//! [`GradSampleLayer`] — batched per-sample-gradient kernels, the native
//! analogue of Opacus's `GradSampleModule` rules (paper §4).
//!
//! Each implementation computes, for a physical batch of B samples in one
//! call: the batched forward pass, the batched input gradient, and the
//! *per-sample* parameter gradients written into a `[B, P_total]` matrix
//! through [`GradSink`]. Keeping per-sample grads materialized mirrors
//! the paper's vectorized-computation design (einsum-style, after Lee &
//! Kifer 2020) and is what per-sample clipping consumes.
//!
//! The trait additionally carries the **norm-only (ghost clipping)
//! protocol** (Lee & Kifer 2020): [`GradSampleLayer::per_sample_sq_norm`]
//! folds each sample's squared parameter-gradient norm into a `[B]`
//! accumulator without ever materializing the `[B, P]` matrix, and
//! [`GradSampleLayer::backward_weighted`] replays the backward with
//! per-sample clip coefficients so the clipped *summed* gradient comes
//! out of a stride-0 [`GradSink`] in O(P) memory. Both are provided
//! methods: custom layers that skip them stay source-compatible but are
//! rejected with a typed error under `ClippingStrategy::Ghost`.
//!
//! This trait is also the **user-defined-layer extension point**: to add
//! a custom layer kind, implement `GradSampleLayer`, include it in a
//! [`NativeModel`](super::model::NativeModel) stack, and register the
//! kind string with the validator
//! ([`validate_model_with_custom`](crate::privacy::validator::validate_model_with_custom)).
//! Built-in kinds mirror `privacy/validator.rs::SUPPORTED`: `linear`,
//! `conv2d`, `embedding`, `layernorm`.
//!
//! Dense contractions (the forward projection, the input gradient, and
//! the summed weight gradient) lower to the blocked [`gemm`] engine —
//! custom layers should reuse [`gemm::sgemm`]/[`gemm::sgemm_nt`]/
//! [`gemm::sgemm_tn`] rather than writing their own loops; `Conv2d`
//! shows the im2col lowering pattern for windowed ops.

use anyhow::{bail, Result};

use crate::rng::{gaussian, Rng};
use crate::runtime::tensor::HostTensor;

use super::gemm;

/// Writes one layer's per-sample parameter gradients into its column
/// block of the model-wide `[B, P_total]` gradient matrix. Rows are
/// zero-initialized by the model, so kernels may accumulate with `+=`.
///
/// With `stride == 0` every sample's row aliases the same `[P_total]`
/// buffer — because kernels accumulate with `+=`, that mode computes the
/// *summed* gradient directly in O(P) memory (the no-DP baseline path,
/// no per-sample materialization).
pub struct GradSink<'a> {
    buf: &'a mut [f32],
    stride: usize,
    offset: usize,
    len: usize,
}

impl<'a> GradSink<'a> {
    pub fn new(buf: &'a mut [f32], stride: usize, offset: usize, len: usize) -> Self {
        debug_assert!(stride == 0 || offset + len <= stride);
        debug_assert!(offset + len <= buf.len());
        GradSink {
            buf,
            stride,
            offset,
            len,
        }
    }

    /// Sample `b`'s gradient slice for this layer (`len` elements).
    /// All samples share one slice when the sink was built with stride 0.
    pub fn row(&mut self, b: usize) -> &mut [f32] {
        let start = b * self.stride + self.offset;
        &mut self.buf[start..start + self.len]
    }

    /// True when the sink was built with stride 0 — every row aliases
    /// one shared `[P]` buffer, i.e. the caller wants the *summed*
    /// gradient. Kernels may then lower the whole batch's weight
    /// gradient to a single `[out, B] × [B, in]` GEMM instead of B
    /// per-sample outer products.
    pub fn is_shared(&self) -> bool {
        self.stride == 0
    }
}

/// Where a backward kernel sends each sample's parameter gradient:
/// either a [`GradSink`] row (the materializing / summed paths), or a
/// reused O(P_layer) scratch buffer whose squared sum is folded into the
/// sample's norm accumulator right after the kernel writes it — the
/// ghost-clipping norm pass. The heavyweight kernels (conv2d, layernorm,
/// the recurrent family, attention) route their one backward body
/// through this, so `backward` and `per_sample_sq_norm` cannot drift
/// apart.
pub(super) enum ParamSink<'a, 'b> {
    /// Write into the per-sample gradient matrix (or its shared row).
    Grad(&'b mut GradSink<'a>),
    /// Stage each sample's gradient in `scratch` (length = the layer's
    /// `num_params()`), then accumulate `Σ g²` into `out[b]`.
    SqNorm {
        scratch: &'b mut [f32],
        out: &'b mut [f64],
    },
}

impl ParamSink<'_, '_> {
    /// Run `f` on sample `s`'s gradient slice. In `SqNorm` mode the
    /// scratch is zeroed first and its squared sum folded into `out[s]`
    /// after `f` returns, so the kernel body is identical either way.
    pub(super) fn with_sample(&mut self, s: usize, f: impl FnOnce(&mut [f32])) {
        match self {
            ParamSink::Grad(gs) => f(gs.row(s)),
            ParamSink::SqNorm { scratch, out } => {
                scratch.fill(0.0);
                f(scratch);
                out[s] += scratch.iter().map(|&v| v as f64 * v as f64).sum::<f64>();
            }
        }
    }
}

/// `dy` with every row of sample `b` scaled by `coeffs[b]` — the default
/// lowering of [`GradSampleLayer::backward_weighted`].
fn scale_rows(dy: &HostTensor, coeffs: &[f32]) -> Result<HostTensor> {
    let b = batch_of(dy);
    if coeffs.len() != b {
        bail!(
            "backward_weighted: {} clip coefficients for a batch of {b}",
            coeffs.len()
        );
    }
    let per = per_sample_elems(dy);
    let mut v = dy.as_f32()?.to_vec();
    for s in 0..b {
        let c = coeffs[s];
        for e in v[s * per..(s + 1) * per].iter_mut() {
            *e *= c;
        }
    }
    Ok(HostTensor::f32(dy.shape.clone(), v))
}

/// A layer with a batched per-sample gradient rule.
///
/// `Send + Sync` is part of the contract: the distributed subsystem
/// shares one immutable model across worker threads, so kernels must
/// keep all mutable scratch local to each call (shard-scoped buffers,
/// never interior mutability on the layer itself).
pub trait GradSampleLayer: Send + Sync {
    /// Kind string as used by the validator (`linear`, `conv2d`, …).
    fn kind(&self) -> &'static str;

    /// Flat parameter count of this layer.
    fn num_params(&self) -> usize;

    /// Per-sample output shape for a per-sample input shape.
    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>>;

    /// Batched forward over `x` = `[B, in...]`; `params` is this layer's
    /// flat slice. Returns `[B, out...]`.
    fn forward(&self, params: &[f32], x: &HostTensor) -> Result<HostTensor>;

    /// Batched backward: `x` is the cached layer input, `dy` the upstream
    /// per-sample gradients `[B, out...]`. Writes per-sample parameter
    /// gradients through `gs` and returns `dx` = `[B, in...]` (f32).
    ///
    /// `need_dx` is false when the caller will discard the input
    /// gradient (the model's first layer) — implementations should then
    /// skip the dx computation and may return an empty `[B, 0]` tensor,
    /// which halves the cost of expensive kernels like conv2d on the
    /// training hot path.
    fn backward(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        gs: &mut GradSink<'_>,
        need_dx: bool,
    ) -> Result<HostTensor>;

    /// True when this layer implements the norm-only (ghost) clipping
    /// protocol — [`Self::per_sample_sq_norm`] plus (directly or through
    /// the provided default) [`Self::backward_weighted`]. Defaults to
    /// `false`: `ClippingStrategy::Ghost` rejects such kinds with a
    /// typed error instead of silently falling back to materialization.
    fn supports_ghost(&self) -> bool {
        false
    }

    /// Norm-only backward — ghost clipping pass 1. Folds each sample's
    /// *squared* parameter-gradient L2 norm into `sqn[b]` without
    /// materializing the `[B, P]` matrix, and returns `dx` exactly as
    /// [`Self::backward`] would (so the pass still propagates upstream
    /// gradients). Implementations use closed forms (linear:
    /// ‖dy_b‖²·(‖x_b‖² + 1)) or an O(P_layer) scratch reused across
    /// samples — never O(B·P) memory.
    fn per_sample_sq_norm(
        &self,
        _params: &[f32],
        _x: &HostTensor,
        _dy: &HostTensor,
        _sqn: &mut [f64],
        _need_dx: bool,
    ) -> Result<HostTensor> {
        bail!(
            "layer kind '{}' does not implement the norm-only (ghost) clipping \
             protocol: implement per_sample_sq_norm (and return true from \
             supports_ghost) on the custom GradSampleLayer, or train with \
             --clipping flat",
            self.kind()
        )
    }

    /// Weighted backward — ghost clipping pass 2. Like [`Self::backward`]
    /// but with sample `b`'s entire contribution (parameter gradients
    /// *and* its `dx` rows) scaled by `coeffs[b]`. Driven with a
    /// stride-0 shared sink this produces the clipped *summed* gradient
    /// directly in O(P) memory — for `Linear`, one stride-0 TN GEMM.
    ///
    /// Every backward in this engine is linear in `dy` given the cached
    /// activations, so the provided default — scale a copy of `dy`
    /// row-wise, then delegate to [`Self::backward`] — is exact; custom
    /// layers only need to override it as an optimization.
    fn backward_weighted(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        coeffs: &[f32],
        gs: &mut GradSink<'_>,
        need_dx: bool,
    ) -> Result<HostTensor> {
        let dyw = scale_rows(dy, coeffs)?;
        self.backward(params, x, &dyw, gs, need_dx)
    }

    /// Deterministic parameter initialization into this layer's slice.
    fn init(&self, params: &mut [f32], rng: &mut dyn Rng);
}

fn batch_of(t: &HostTensor) -> usize {
    *t.shape.first().unwrap_or(&0)
}

fn per_sample_elems(t: &HostTensor) -> usize {
    t.shape[1..].iter().product()
}

// Dense contractions shared by every projection-style layer (Linear
// here, plus the recurrent and attention modules) route through the
// blocked [`gemm`] micro-kernels: one engine so the register/cache
// tiling lands everywhere at once. The only scalar kernel left is the
// per-sample rank-1 outer product below — a sample's weight gradient
// `dy_b ⊗ x_b` has no batch dimension to block over, and it is exactly
// what the `[B, P]` per-sample materialization must write per row.

/// `G[rows, cols] += u[rows] ⊗ v[cols]` (row-major `G`).
#[inline]
pub(super) fn outer_acc(g: &mut [f32], u: &[f32], v: &[f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let d = u[r];
        if d == 0.0 {
            continue;
        }
        let gr = &mut g[r * cols..(r + 1) * cols];
        for c in 0..cols {
            gr[c] += d * v[c];
        }
    }
}

// ---------------------------------------------------------------- Linear

/// Fully connected layer, `y = W x + b`. Accepts any input whose
/// per-sample element count equals `in_dim` (implicit flatten).
pub struct Linear {
    pub in_dim: usize,
    pub out_dim: usize,
}

impl Linear {
    pub fn new(in_dim: usize, out_dim: usize) -> Self {
        Linear { in_dim, out_dim }
    }
}

impl GradSampleLayer for Linear {
    fn kind(&self) -> &'static str {
        "linear"
    }

    fn num_params(&self) -> usize {
        self.out_dim * self.in_dim + self.out_dim
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>> {
        let n: usize = in_shape.iter().product();
        if n != self.in_dim {
            bail!(
                "linear: input shape {in_shape:?} has {n} elements, expected {}",
                self.in_dim
            );
        }
        Ok(vec![self.out_dim])
    }

    fn forward(&self, params: &[f32], x: &HostTensor) -> Result<HostTensor> {
        let b = batch_of(x);
        let xs = x.as_f32()?;
        if per_sample_elems(x) != self.in_dim {
            bail!("linear forward: bad input shape {:?}", x.shape);
        }
        let (ind, outd) = (self.in_dim, self.out_dim);
        let w = &params[..outd * ind];
        let bias = &params[outd * ind..];
        // one [B, in] × [in, out] GEMM over bias-initialized rows
        let mut y = vec![0f32; b * outd];
        for s in 0..b {
            y[s * outd..(s + 1) * outd].copy_from_slice(bias);
        }
        gemm::sgemm_nt(b, outd, ind, xs, ind, w, ind, &mut y, outd);
        Ok(HostTensor::f32(vec![b, outd], y))
    }

    fn backward(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        gs: &mut GradSink<'_>,
        need_dx: bool,
    ) -> Result<HostTensor> {
        let b = batch_of(x);
        let xs = x.as_f32()?;
        let dys = dy.as_f32()?;
        let (ind, outd) = (self.in_dim, self.out_dim);
        let w = &params[..outd * ind];
        if gs.is_shared() {
            // summed gradient: one [out, B] × [B, in] outer-product GEMM
            let g = gs.row(0);
            gemm::sgemm_tn(outd, ind, b, dys, outd, xs, ind, &mut g[..outd * ind], ind);
            let gb = &mut g[outd * ind..];
            for s in 0..b {
                let dyr = &dys[s * outd..(s + 1) * outd];
                for o in 0..outd {
                    gb[o] += dyr[o];
                }
            }
        } else {
            // per-sample gradient rows: one rank-1 outer product each
            for s in 0..b {
                let xr = &xs[s * ind..(s + 1) * ind];
                let dyr = &dys[s * outd..(s + 1) * outd];
                let g = gs.row(s);
                outer_acc(&mut g[..outd * ind], dyr, xr, outd, ind);
                let gb = &mut g[outd * ind..];
                for o in 0..outd {
                    gb[o] += dyr[o];
                }
            }
        }
        if !need_dx {
            return Ok(HostTensor::f32(vec![b, 0], Vec::new()));
        }
        // dX[B, in] = dY[B, out] · W[out, in] in one GEMM
        let mut dx = vec![0f32; b * ind];
        gemm::sgemm(b, ind, outd, dys, outd, w, ind, &mut dx, ind);
        let mut shape = vec![b];
        shape.extend_from_slice(&x.shape[1..]);
        Ok(HostTensor::f32(shape, dx))
    }

    fn supports_ghost(&self) -> bool {
        true
    }

    fn per_sample_sq_norm(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        sqn: &mut [f64],
        need_dx: bool,
    ) -> Result<HostTensor> {
        let b = batch_of(x);
        let xs = x.as_f32()?;
        let dys = dy.as_f32()?;
        let (ind, outd) = (self.in_dim, self.out_dim);
        // dW_b = dy_b ⊗ x_b is rank-1, so ‖dW_b‖² = ‖dy_b‖²·‖x_b‖² and
        // ‖db_b‖² = ‖dy_b‖² — O(B·(in + out)) instead of O(B·P).
        for s in 0..b {
            let x2: f64 = xs[s * ind..(s + 1) * ind]
                .iter()
                .map(|&v| v as f64 * v as f64)
                .sum();
            let dy2: f64 = dys[s * outd..(s + 1) * outd]
                .iter()
                .map(|&v| v as f64 * v as f64)
                .sum();
            sqn[s] += dy2 * (x2 + 1.0);
        }
        if !need_dx {
            return Ok(HostTensor::f32(vec![b, 0], Vec::new()));
        }
        let w = &params[..outd * ind];
        let mut dx = vec![0f32; b * ind];
        gemm::sgemm(b, ind, outd, dys, outd, w, ind, &mut dx, ind);
        let mut shape = vec![b];
        shape.extend_from_slice(&x.shape[1..]);
        Ok(HostTensor::f32(shape, dx))
    }

    fn init(&self, params: &mut [f32], rng: &mut dyn Rng) {
        let nw = self.out_dim * self.in_dim;
        gaussian::fill_standard_normal(rng, &mut params[..nw]);
        let scale = (2.0 / self.in_dim as f64).sqrt() as f32;
        for p in params[..nw].iter_mut() {
            *p *= scale;
        }
        params[nw..].fill(0.0);
    }
}

// ---------------------------------------------------------------- Conv2d

/// 2-D convolution over NHWC inputs with square kernel, stride and
/// symmetric zero padding.
pub struct Conv2d {
    pub in_c: usize,
    pub out_c: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2d {
    pub fn new(in_c: usize, out_c: usize, k: usize, stride: usize, pad: usize) -> Self {
        Conv2d {
            in_c,
            out_c,
            k,
            stride,
            pad,
        }
    }

    fn out_hw(&self, h: usize, w: usize) -> Result<(usize, usize)> {
        let span = |n: usize| -> Result<usize> {
            let padded = n + 2 * self.pad;
            if padded < self.k {
                bail!("conv2d: input {n} smaller than kernel {} (pad {})", self.k, self.pad);
            }
            Ok((padded - self.k) / self.stride + 1)
        };
        Ok((span(h)?, span(w)?))
    }

    /// Columns of the im2col matrix: one `[ky][kx][ic]` patch per output
    /// position — the same ordering as the flat weight layout, so the
    /// convolution lowers to `col · Wᵀ` on the shared GEMM engine.
    fn col_width(&self) -> usize {
        self.k * self.k * self.in_c
    }

    /// im2col of one sample: `col[oh·ow, k·k·ic]` with out-of-image taps
    /// left at zero (`col` is fully overwritten).
    fn im2col(&self, xr: &[f32], h: usize, w: usize, oh: usize, ow: usize, col: &mut [f32]) {
        let (ic, k, s, p) = (self.in_c, self.k, self.stride, self.pad);
        let cw = self.col_width();
        col.fill(0.0);
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut col[(oy * ow + ox) * cw..(oy * ow + ox + 1) * cw];
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - p as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - p as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let xbase = (iy as usize * w + ix as usize) * ic;
                        let dbase = (ky * k + kx) * ic;
                        dst[dbase..dbase + ic].copy_from_slice(&xr[xbase..xbase + ic]);
                    }
                }
            }
        }
    }

    /// Adjoint of [`im2col`](Self::im2col): scatter-add col-space
    /// gradients back into image space (`dxr` accumulates).
    fn col2im(&self, dcol: &[f32], h: usize, w: usize, oh: usize, ow: usize, dxr: &mut [f32]) {
        let (ic, k, s, p) = (self.in_c, self.k, self.stride, self.pad);
        let cw = self.col_width();
        for oy in 0..oh {
            for ox in 0..ow {
                let src = &dcol[(oy * ow + ox) * cw..(oy * ow + ox + 1) * cw];
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - p as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - p as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let xbase = (iy as usize * w + ix as usize) * ic;
                        let sbase = (ky * k + kx) * ic;
                        for c in 0..ic {
                            dxr[xbase + c] += src[sbase + c];
                        }
                    }
                }
            }
        }
    }

    /// One backward body for both the materializing and norm-only paths:
    /// the per-sample `dW/db` write lands wherever `sink` points.
    fn backward_core(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        sink: &mut ParamSink<'_, '_>,
        need_dx: bool,
    ) -> Result<HostTensor> {
        let b = batch_of(x);
        let &[h, w, _] = &x.shape[1..] else {
            bail!("conv2d backward: bad input shape {:?}", x.shape);
        };
        let (oh, ow) = self.out_hw(h, w)?;
        let xs = x.as_f32()?;
        let dys = dy.as_f32()?;
        let (ic, oc) = (self.in_c, self.out_c);
        let cw = self.col_width();
        let wts = &params[..oc * cw];
        let nw = oc * cw;
        let mut dx = if need_dx {
            vec![0f32; b * h * w * ic]
        } else {
            Vec::new()
        };
        let mut col = vec![0f32; oh * ow * cw];
        let mut dcol = if need_dx {
            vec![0f32; oh * ow * cw]
        } else {
            Vec::new()
        };
        for smp in 0..b {
            let xr = &xs[smp * h * w * ic..(smp + 1) * h * w * ic];
            let dyr = &dys[smp * oh * ow * oc..(smp + 1) * oh * ow * oc];
            self.im2col(xr, h, w, oh, ow, &mut col);
            sink.with_sample(smp, |g| {
                // dW[oc, cw] += dyᵀ[oc, oh·ow] · col[oh·ow, cw]
                gemm::sgemm_tn(oc, cw, oh * ow, dyr, oc, &col, cw, &mut g[..nw], cw);
                for pos in 0..oh * ow {
                    for o in 0..oc {
                        g[nw + o] += dyr[pos * oc + o];
                    }
                }
            });
            if need_dx {
                // dcol[oh·ow, cw] = dy[oh·ow, oc] · W[oc, cw], then the
                // col2im scatter-add back to image space
                dcol.fill(0.0);
                gemm::sgemm(oh * ow, cw, oc, dyr, oc, wts, cw, &mut dcol, cw);
                let dxr = &mut dx[smp * h * w * ic..(smp + 1) * h * w * ic];
                self.col2im(&dcol, h, w, oh, ow, dxr);
            }
        }
        if !need_dx {
            return Ok(HostTensor::f32(vec![b, 0], dx));
        }
        Ok(HostTensor::f32(vec![b, h, w, ic], dx))
    }
}

impl GradSampleLayer for Conv2d {
    fn kind(&self) -> &'static str {
        "conv2d"
    }

    fn num_params(&self) -> usize {
        self.out_c * self.k * self.k * self.in_c + self.out_c
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>> {
        let [h, w, c] = in_shape else {
            bail!("conv2d: expected [H, W, C] input, got {in_shape:?}");
        };
        if *c != self.in_c {
            bail!("conv2d: input channels {c} != {}", self.in_c);
        }
        let (oh, ow) = self.out_hw(*h, *w)?;
        Ok(vec![oh, ow, self.out_c])
    }

    fn forward(&self, params: &[f32], x: &HostTensor) -> Result<HostTensor> {
        let b = batch_of(x);
        let &[h, w, _] = &x.shape[1..] else {
            bail!("conv2d forward: bad input shape {:?}", x.shape);
        };
        let (oh, ow) = self.out_hw(h, w)?;
        let xs = x.as_f32()?;
        let (ic, oc) = (self.in_c, self.out_c);
        let cw = self.col_width();
        let wts = &params[..oc * cw];
        let bias = &params[oc * cw..];
        // im2col lowering: per sample, y[oh·ow, oc] = col[oh·ow, cw] · Wᵀ
        let mut col = vec![0f32; oh * ow * cw];
        let mut y = vec![0f32; b * oh * ow * oc];
        for smp in 0..b {
            let xr = &xs[smp * h * w * ic..(smp + 1) * h * w * ic];
            self.im2col(xr, h, w, oh, ow, &mut col);
            let yr = &mut y[smp * oh * ow * oc..(smp + 1) * oh * ow * oc];
            for pos in 0..oh * ow {
                yr[pos * oc..(pos + 1) * oc].copy_from_slice(bias);
            }
            gemm::sgemm_nt(oh * ow, oc, cw, &col, cw, wts, cw, yr, oc);
        }
        Ok(HostTensor::f32(vec![b, oh, ow, oc], y))
    }

    fn backward(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        gs: &mut GradSink<'_>,
        need_dx: bool,
    ) -> Result<HostTensor> {
        self.backward_core(params, x, dy, &mut ParamSink::Grad(gs), need_dx)
    }

    fn supports_ghost(&self) -> bool {
        true
    }

    fn per_sample_sq_norm(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        sqn: &mut [f64],
        need_dx: bool,
    ) -> Result<HostTensor> {
        let mut scratch = vec![0f32; self.num_params()];
        let mut sink = ParamSink::SqNorm {
            scratch: &mut scratch,
            out: sqn,
        };
        self.backward_core(params, x, dy, &mut sink, need_dx)
    }

    fn init(&self, params: &mut [f32], rng: &mut dyn Rng) {
        let nw = self.out_c * self.k * self.k * self.in_c;
        gaussian::fill_standard_normal(rng, &mut params[..nw]);
        let fan_in = (self.k * self.k * self.in_c) as f64;
        let scale = (2.0 / fan_in).sqrt() as f32;
        for p in params[..nw].iter_mut() {
            *p *= scale;
        }
        params[nw..].fill(0.0);
    }
}

// ------------------------------------------------------------- Embedding

/// Token embedding lookup: i32 tokens `[B, T]` → `[B, T, dim]`.
pub struct Embedding {
    pub vocab: usize,
    pub dim: usize,
}

impl Embedding {
    pub fn new(vocab: usize, dim: usize) -> Self {
        Embedding { vocab, dim }
    }
}

impl GradSampleLayer for Embedding {
    fn kind(&self) -> &'static str {
        "embedding"
    }

    fn num_params(&self) -> usize {
        self.vocab * self.dim
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>> {
        let [t] = in_shape else {
            bail!("embedding: expected [T] token input, got {in_shape:?}");
        };
        Ok(vec![*t, self.dim])
    }

    fn forward(&self, params: &[f32], x: &HostTensor) -> Result<HostTensor> {
        let b = batch_of(x);
        let t = per_sample_elems(x);
        let toks = x.as_i32()?;
        let d = self.dim;
        let mut y = vec![0f32; b * t * d];
        for (pos, &tok) in toks.iter().enumerate() {
            if tok < 0 || tok as usize >= self.vocab {
                bail!("embedding: token {tok} out of range [0, {})", self.vocab);
            }
            let row = &params[tok as usize * d..(tok as usize + 1) * d];
            y[pos * d..(pos + 1) * d].copy_from_slice(row);
        }
        Ok(HostTensor::f32(vec![b, t, d], y))
    }

    fn backward(
        &self,
        _params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        gs: &mut GradSink<'_>,
        _need_dx: bool,
    ) -> Result<HostTensor> {
        let b = batch_of(x);
        let t = per_sample_elems(x);
        let toks = x.as_i32()?;
        let dys = dy.as_f32()?;
        let d = self.dim;
        for smp in 0..b {
            let g = gs.row(smp);
            for pos in 0..t {
                let tok = toks[smp * t + pos] as usize;
                let dyr = &dys[(smp * t + pos) * d..(smp * t + pos + 1) * d];
                let gr = &mut g[tok * d..(tok + 1) * d];
                for j in 0..d {
                    gr[j] += dyr[j];
                }
            }
        }
        // tokens carry no gradient regardless of need_dx
        Ok(HostTensor::f32(vec![b, 0], Vec::new()))
    }

    fn supports_ghost(&self) -> bool {
        true
    }

    fn per_sample_sq_norm(
        &self,
        _params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        sqn: &mut [f64],
        _need_dx: bool,
    ) -> Result<HostTensor> {
        let b = batch_of(x);
        let t = per_sample_elems(x);
        let toks = x.as_i32()?;
        let dys = dy.as_f32()?;
        let d = self.dim;
        // A sample touches ≤ T of the V vocab rows, so its gradient lives
        // in a [T, d] scratch keyed by distinct token: accumulate repeats
        // in position order (exactly as `backward` does into the full
        // row), then square — O(B·T·d) memory-free of the vocab size.
        let mut acc = vec![0f32; t * d];
        let mut seen: Vec<i32> = Vec::with_capacity(t);
        for smp in 0..b {
            seen.clear();
            for pos in 0..t {
                let tok = toks[smp * t + pos];
                let dyr = &dys[(smp * t + pos) * d..(smp * t + pos + 1) * d];
                match seen.iter().position(|&s| s == tok) {
                    Some(i) => {
                        let ar = &mut acc[i * d..(i + 1) * d];
                        for j in 0..d {
                            ar[j] += dyr[j];
                        }
                    }
                    None => {
                        acc[seen.len() * d..(seen.len() + 1) * d].copy_from_slice(dyr);
                        seen.push(tok);
                    }
                }
            }
            sqn[smp] += acc[..seen.len() * d]
                .iter()
                .map(|&v| v as f64 * v as f64)
                .sum::<f64>();
        }
        Ok(HostTensor::f32(vec![b, 0], Vec::new()))
    }

    fn init(&self, params: &mut [f32], rng: &mut dyn Rng) {
        gaussian::fill_standard_normal(rng, params);
        for p in params.iter_mut() {
            *p *= 0.1;
        }
    }
}

// ------------------------------------------------------------- LayerNorm

/// Layer normalization over the last axis, with learnable scale and
/// shift (`gamma`, `beta`).
pub struct LayerNorm {
    pub dim: usize,
    pub eps: f64,
}

impl LayerNorm {
    pub fn new(dim: usize) -> Self {
        LayerNorm { dim, eps: 1e-5 }
    }

    /// One backward body for both the materializing and norm-only paths.
    fn backward_core(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        sink: &mut ParamSink<'_, '_>,
        need_dx: bool,
    ) -> Result<HostTensor> {
        let b = batch_of(x);
        let xs = x.as_f32()?;
        let dys = dy.as_f32()?;
        let d = self.dim;
        let rows_per_sample = per_sample_elems(x) / d;
        let gamma = &params[..d];
        let mut dx = if need_dx {
            vec![0f32; xs.len()]
        } else {
            Vec::new()
        };
        for smp in 0..b {
            sink.with_sample(smp, |g| {
                for rr in 0..rows_per_sample {
                    let r = smp * rows_per_sample + rr;
                    let xr = &xs[r * d..(r + 1) * d];
                    let dyr = &dys[r * d..(r + 1) * d];
                    let (mu, inv) = row_stats(xr, self.eps);
                    let mut m1 = 0.0f64; // mean(dxhat)
                    let mut m2 = 0.0f64; // mean(dxhat * xhat)
                    for j in 0..d {
                        let xhat = (xr[j] as f64 - mu) * inv;
                        let dxhat = dyr[j] as f64 * gamma[j] as f64;
                        m1 += dxhat;
                        m2 += dxhat * xhat;
                        // per-sample parameter grads: dgamma then dbeta
                        g[j] += (dyr[j] as f64 * xhat) as f32;
                        g[d + j] += dyr[j];
                    }
                    if need_dx {
                        m1 /= d as f64;
                        m2 /= d as f64;
                        let dxr = &mut dx[r * d..(r + 1) * d];
                        for j in 0..d {
                            let xhat = (xr[j] as f64 - mu) * inv;
                            let dxhat = dyr[j] as f64 * gamma[j] as f64;
                            dxr[j] = (inv * (dxhat - m1 - xhat * m2)) as f32;
                        }
                    }
                }
            });
        }
        if !need_dx {
            return Ok(HostTensor::f32(vec![b, 0], dx));
        }
        Ok(HostTensor::f32(x.shape.clone(), dx))
    }
}

impl GradSampleLayer for LayerNorm {
    fn kind(&self) -> &'static str {
        "layernorm"
    }

    fn num_params(&self) -> usize {
        2 * self.dim
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>> {
        match in_shape.last() {
            Some(&d) if d == self.dim => Ok(in_shape.to_vec()),
            other => bail!(
                "layernorm: last input axis {other:?} != normalized dim {}",
                self.dim
            ),
        }
    }

    fn forward(&self, params: &[f32], x: &HostTensor) -> Result<HostTensor> {
        let xs = x.as_f32()?;
        let d = self.dim;
        let rows = xs.len() / d;
        let gamma = &params[..d];
        let beta = &params[d..];
        let mut y = vec![0f32; xs.len()];
        for r in 0..rows {
            let xr = &xs[r * d..(r + 1) * d];
            let yr = &mut y[r * d..(r + 1) * d];
            let (mu, inv) = row_stats(xr, self.eps);
            for j in 0..d {
                let xhat = (xr[j] as f64 - mu) * inv;
                yr[j] = (xhat * gamma[j] as f64 + beta[j] as f64) as f32;
            }
        }
        Ok(HostTensor::f32(x.shape.clone(), y))
    }

    fn backward(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        gs: &mut GradSink<'_>,
        need_dx: bool,
    ) -> Result<HostTensor> {
        self.backward_core(params, x, dy, &mut ParamSink::Grad(gs), need_dx)
    }

    fn supports_ghost(&self) -> bool {
        true
    }

    fn per_sample_sq_norm(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        sqn: &mut [f64],
        need_dx: bool,
    ) -> Result<HostTensor> {
        let mut scratch = vec![0f32; self.num_params()];
        let mut sink = ParamSink::SqNorm {
            scratch: &mut scratch,
            out: sqn,
        };
        self.backward_core(params, x, dy, &mut sink, need_dx)
    }

    fn init(&self, params: &mut [f32], _rng: &mut dyn Rng) {
        let d = self.dim;
        params[..d].fill(1.0);
        params[d..].fill(0.0);
    }
}

/// (mean, 1/√(var + eps)) of one normalization row, in f64.
fn row_stats(xr: &[f32], eps: f64) -> (f64, f64) {
    let n = xr.len() as f64;
    let mu = xr.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = xr.iter().map(|&v| (v as f64 - mu).powi(2)).sum::<f64>() / n;
    (mu, 1.0 / (var + eps).sqrt())
}

#[cfg(test)]
mod tests {
    use super::super::test_util::init_layer_params as init_params;
    use super::*;

    #[test]
    fn linear_forward_matches_manual() {
        let l = Linear::new(2, 2);
        // W = [[1, 2], [3, 4]], b = [0.5, -0.5]
        let params = vec![1.0, 2.0, 3.0, 4.0, 0.5, -0.5];
        let x = HostTensor::f32(vec![2, 2], vec![1.0, 1.0, 0.0, 2.0]);
        let y = l.forward(&params, &x).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[3.5, 6.5, 4.5, 7.5]);
    }

    #[test]
    fn linear_backward_per_sample_grads() {
        let l = Linear::new(2, 1);
        let params = vec![2.0, -1.0, 0.0]; // W = [2, -1], b = 0
        let x = HostTensor::f32(vec![2, 2], vec![1.0, 3.0, -2.0, 0.5]);
        let dy = HostTensor::f32(vec![2, 1], vec![1.0, 2.0]);
        let mut buf = vec![0f32; 2 * 3];
        let mut gs = GradSink::new(&mut buf, 3, 0, 3);
        let dx = l.backward(&params, &x, &dy, &mut gs, true).unwrap();
        // sample 0: dW = 1·x = [1, 3], db = 1; sample 1: dW = 2·x = [-4, 1], db = 2
        assert_eq!(buf, vec![1.0, 3.0, 1.0, -4.0, 1.0, 2.0]);
        // dx = dy · W
        assert_eq!(dx.as_f32().unwrap(), &[2.0, -1.0, 4.0, -2.0]);

        // need_dx = false: identical parameter grads, empty dx
        let mut buf2 = vec![0f32; 2 * 3];
        let mut gs2 = GradSink::new(&mut buf2, 3, 0, 3);
        let dx2 = l.backward(&params, &x, &dy, &mut gs2, false).unwrap();
        assert_eq!(buf2, buf);
        assert!(dx2.is_empty());

        // stride-0 shared sink: rows accumulate into one summed gradient
        let mut gsum = vec![0f32; 3];
        let mut shared = GradSink::new(&mut gsum, 0, 0, 3);
        l.backward(&params, &x, &dy, &mut shared, false).unwrap();
        assert_eq!(gsum, vec![1.0 - 4.0, 3.0 + 1.0, 1.0 + 2.0]);
    }

    #[test]
    fn conv2d_shapes() {
        let c = Conv2d::new(1, 8, 3, 2, 1);
        assert_eq!(c.out_shape(&[28, 28, 1]).unwrap(), vec![14, 14, 8]);
        assert!(c.out_shape(&[28, 28, 3]).is_err());
        let c = Conv2d::new(3, 4, 3, 1, 0);
        assert_eq!(c.out_shape(&[8, 8, 3]).unwrap(), vec![6, 6, 4]);
    }

    #[test]
    fn conv2d_identity_kernel_passes_through() {
        // 1x1 kernel, single channel, weight 1, bias 0: y == x
        let c = Conv2d::new(1, 1, 1, 1, 0);
        let params = vec![1.0, 0.0];
        let x = HostTensor::f32(vec![1, 2, 2, 1], vec![1.0, -2.0, 3.0, 4.0]);
        let y = c.forward(&params, &x).unwrap();
        assert_eq!(y.as_f32().unwrap(), x.as_f32().unwrap());
        // and its backward returns dy as dx with dW = Σ x·dy, db = Σ dy
        let dy = HostTensor::f32(vec![1, 2, 2, 1], vec![1.0, 1.0, 1.0, 1.0]);
        let mut buf = vec![0f32; 2];
        let mut gs = GradSink::new(&mut buf, 2, 0, 2);
        let dx = c.backward(&params, &x, &dy, &mut gs, true).unwrap();
        assert_eq!(dx.as_f32().unwrap(), &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(buf, vec![6.0, 4.0]); // Σx = 6, Σdy = 4
    }

    #[test]
    fn embedding_lookup_and_grad() {
        let e = Embedding::new(4, 2);
        let params = vec![0., 0., 1., 2., 3., 4., 5., 6.]; // rows 0..4
        let x = HostTensor::i32(vec![1, 3], vec![1, 3, 1]);
        let y = e.forward(&params, &x).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[1., 2., 5., 6., 1., 2.]);
        // repeated token 1 must accumulate
        let dy = HostTensor::f32(vec![1, 3, 2], vec![1., 1., 1., 1., 1., 1.]);
        let mut buf = vec![0f32; 8];
        let mut gs = GradSink::new(&mut buf, 8, 0, 8);
        e.backward(&params, &x, &dy, &mut gs, true).unwrap();
        assert_eq!(buf, vec![0., 0., 2., 2., 0., 0., 1., 1.]);
        // out-of-range tokens are an error, not UB
        let bad = HostTensor::i32(vec![1, 1], vec![4]);
        assert!(e.forward(&params, &bad).is_err());
    }

    #[test]
    fn layernorm_normalizes_rows() {
        let ln = LayerNorm::new(4);
        let params = init_params(&ln, 0); // gamma = 1, beta = 0
        let x = HostTensor::f32(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let y = ln.forward(&params, &x).unwrap();
        let ys = y.as_f32().unwrap();
        let mean: f32 = ys.iter().sum::<f32>() / 4.0;
        let var: f32 = ys.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn layernorm_grad_orthogonal_to_constants() {
        // dx of layernorm must sum to ~0 along the normalized axis
        let ln = LayerNorm::new(4);
        let params = init_params(&ln, 0);
        let x = HostTensor::f32(vec![1, 4], vec![0.3, -1.2, 2.0, 0.7]);
        let dy = HostTensor::f32(vec![1, 4], vec![1.0, -0.5, 0.25, 2.0]);
        let mut buf = vec![0f32; 8];
        let mut gs = GradSink::new(&mut buf, 8, 0, 8);
        let dx = ln.backward(&params, &x, &dy, &mut gs, true).unwrap();
        let s: f32 = dx.as_f32().unwrap().iter().sum();
        assert!(s.abs() < 1e-5, "Σdx = {s}");
        // dbeta = dy
        assert_eq!(&buf[4..], dy.as_f32().unwrap());
    }

    #[test]
    fn ghost_protocol_matches_materialized_per_sample_norms() {
        use crate::rng::gaussian::fill_standard_normal;
        use crate::rng::pcg::Xoshiro256pp;
        let mut rng = Xoshiro256pp::seed_from_u64(41);
        let mut gauss = |n: usize| {
            let mut v = vec![0f32; n];
            fill_standard_normal(&mut rng, &mut v);
            v
        };
        // linear: closed-form rank-1 norms vs materialized rows
        let l = Linear::new(3, 2);
        let params = init_params(&l, 1);
        let x = HostTensor::f32(vec![4, 3], gauss(12));
        let dy = HostTensor::f32(vec![4, 2], gauss(8));
        super::super::test_util::ghost_check(&l, &params, &x, &dy);
        // conv2d: scratch-reuse of the im2col backward body
        let c = Conv2d::new(2, 3, 3, 1, 1);
        let params = init_params(&c, 2);
        let x = HostTensor::f32(vec![4, 5, 5, 2], gauss(4 * 5 * 5 * 2));
        let dy = HostTensor::f32(vec![4, 5, 5, 3], gauss(4 * 5 * 5 * 3));
        super::super::test_util::ghost_check(&c, &params, &x, &dy);
        // embedding: distinct-token accumulation (tokens 1 and 3 repeat)
        let e = Embedding::new(10, 4);
        let params = init_params(&e, 3);
        let x = HostTensor::i32(vec![4, 6], vec![
            1, 3, 1, 0, 9, 3, //
            2, 2, 2, 2, 2, 2, //
            5, 6, 7, 8, 9, 0, //
            3, 1, 3, 1, 3, 1,
        ]);
        let dy = HostTensor::f32(vec![4, 6, 4], gauss(4 * 6 * 4));
        super::super::test_util::ghost_check(&e, &params, &x, &dy);
        // layernorm: per-row gamma/beta grads through the shared body
        let ln = LayerNorm::new(6);
        let params = init_params(&ln, 4);
        let x = HostTensor::f32(vec![4, 6], gauss(24));
        let dy = HostTensor::f32(vec![4, 6], gauss(24));
        super::super::test_util::ghost_check(&ln, &params, &x, &dy);
    }

    #[test]
    fn ghost_rejects_mismatched_coefficient_counts() {
        let l = Linear::new(2, 2);
        let params = init_params(&l, 5);
        let x = HostTensor::f32(vec![3, 2], vec![0.5; 6]);
        let dy = HostTensor::f32(vec![3, 2], vec![0.1; 6]);
        let mut buf = vec![0f32; 6];
        let mut gs = GradSink::new(&mut buf, 0, 0, 6);
        let err = l
            .backward_weighted(&params, &x, &dy, &[1.0, 1.0], &mut gs, false)
            .unwrap_err()
            .to_string();
        assert!(err.contains("clip coefficients"), "{err}");
    }

    #[test]
    fn init_is_deterministic() {
        let l = Linear::new(8, 4);
        assert_eq!(init_params(&l, 7), init_params(&l, 7));
        assert_ne!(init_params(&l, 7), init_params(&l, 8));
    }
}
