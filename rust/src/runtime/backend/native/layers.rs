//! [`GradSampleLayer`] — batched per-sample-gradient kernels, the native
//! analogue of Opacus's `GradSampleModule` rules (paper §4).
//!
//! Each implementation computes, for a physical batch of B samples in one
//! call: the batched forward pass, the batched input gradient, and the
//! *per-sample* parameter gradients written into a `[B, P_total]` matrix
//! through [`GradSink`]. Keeping per-sample grads materialized mirrors
//! the paper's vectorized-computation design (einsum-style, after Lee &
//! Kifer 2020) and is what per-sample clipping consumes.
//!
//! This trait is also the **user-defined-layer extension point**: to add
//! a custom layer kind, implement `GradSampleLayer`, include it in a
//! [`NativeModel`](super::model::NativeModel) stack, and register the
//! kind string with the validator
//! ([`validate_model_with_custom`](crate::privacy::validator::validate_model_with_custom)).
//! Built-in kinds mirror `privacy/validator.rs::SUPPORTED`: `linear`,
//! `conv2d`, `embedding`, `layernorm`.
//!
//! Dense contractions (the forward projection, the input gradient, and
//! the summed weight gradient) lower to the blocked [`gemm`] engine —
//! custom layers should reuse [`gemm::sgemm`]/[`gemm::sgemm_nt`]/
//! [`gemm::sgemm_tn`] rather than writing their own loops; `Conv2d`
//! shows the im2col lowering pattern for windowed ops.

use anyhow::{bail, Result};

use crate::rng::{gaussian, Rng};
use crate::runtime::tensor::HostTensor;

use super::gemm;

/// Writes one layer's per-sample parameter gradients into its column
/// block of the model-wide `[B, P_total]` gradient matrix. Rows are
/// zero-initialized by the model, so kernels may accumulate with `+=`.
///
/// With `stride == 0` every sample's row aliases the same `[P_total]`
/// buffer — because kernels accumulate with `+=`, that mode computes the
/// *summed* gradient directly in O(P) memory (the no-DP baseline path,
/// no per-sample materialization).
pub struct GradSink<'a> {
    buf: &'a mut [f32],
    stride: usize,
    offset: usize,
    len: usize,
}

impl<'a> GradSink<'a> {
    pub fn new(buf: &'a mut [f32], stride: usize, offset: usize, len: usize) -> Self {
        debug_assert!(stride == 0 || offset + len <= stride);
        debug_assert!(offset + len <= buf.len());
        GradSink {
            buf,
            stride,
            offset,
            len,
        }
    }

    /// Sample `b`'s gradient slice for this layer (`len` elements).
    /// All samples share one slice when the sink was built with stride 0.
    pub fn row(&mut self, b: usize) -> &mut [f32] {
        let start = b * self.stride + self.offset;
        &mut self.buf[start..start + self.len]
    }

    /// True when the sink was built with stride 0 — every row aliases
    /// one shared `[P]` buffer, i.e. the caller wants the *summed*
    /// gradient. Kernels may then lower the whole batch's weight
    /// gradient to a single `[out, B] × [B, in]` GEMM instead of B
    /// per-sample outer products.
    pub fn is_shared(&self) -> bool {
        self.stride == 0
    }
}

/// A layer with a batched per-sample gradient rule.
///
/// `Send + Sync` is part of the contract: the distributed subsystem
/// shares one immutable model across worker threads, so kernels must
/// keep all mutable scratch local to each call (shard-scoped buffers,
/// never interior mutability on the layer itself).
pub trait GradSampleLayer: Send + Sync {
    /// Kind string as used by the validator (`linear`, `conv2d`, …).
    fn kind(&self) -> &'static str;

    /// Flat parameter count of this layer.
    fn num_params(&self) -> usize;

    /// Per-sample output shape for a per-sample input shape.
    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>>;

    /// Batched forward over `x` = `[B, in...]`; `params` is this layer's
    /// flat slice. Returns `[B, out...]`.
    fn forward(&self, params: &[f32], x: &HostTensor) -> Result<HostTensor>;

    /// Batched backward: `x` is the cached layer input, `dy` the upstream
    /// per-sample gradients `[B, out...]`. Writes per-sample parameter
    /// gradients through `gs` and returns `dx` = `[B, in...]` (f32).
    ///
    /// `need_dx` is false when the caller will discard the input
    /// gradient (the model's first layer) — implementations should then
    /// skip the dx computation and may return an empty `[B, 0]` tensor,
    /// which halves the cost of expensive kernels like conv2d on the
    /// training hot path.
    fn backward(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        gs: &mut GradSink<'_>,
        need_dx: bool,
    ) -> Result<HostTensor>;

    /// Deterministic parameter initialization into this layer's slice.
    fn init(&self, params: &mut [f32], rng: &mut dyn Rng);
}

fn batch_of(t: &HostTensor) -> usize {
    *t.shape.first().unwrap_or(&0)
}

fn per_sample_elems(t: &HostTensor) -> usize {
    t.shape[1..].iter().product()
}

// Dense contractions shared by every projection-style layer (Linear
// here, plus the recurrent and attention modules) route through the
// blocked [`gemm`] micro-kernels: one engine so the register/cache
// tiling lands everywhere at once. The only scalar kernel left is the
// per-sample rank-1 outer product below — a sample's weight gradient
// `dy_b ⊗ x_b` has no batch dimension to block over, and it is exactly
// what the `[B, P]` per-sample materialization must write per row.

/// `G[rows, cols] += u[rows] ⊗ v[cols]` (row-major `G`).
#[inline]
pub(super) fn outer_acc(g: &mut [f32], u: &[f32], v: &[f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let d = u[r];
        if d == 0.0 {
            continue;
        }
        let gr = &mut g[r * cols..(r + 1) * cols];
        for c in 0..cols {
            gr[c] += d * v[c];
        }
    }
}

// ---------------------------------------------------------------- Linear

/// Fully connected layer, `y = W x + b`. Accepts any input whose
/// per-sample element count equals `in_dim` (implicit flatten).
pub struct Linear {
    pub in_dim: usize,
    pub out_dim: usize,
}

impl Linear {
    pub fn new(in_dim: usize, out_dim: usize) -> Self {
        Linear { in_dim, out_dim }
    }
}

impl GradSampleLayer for Linear {
    fn kind(&self) -> &'static str {
        "linear"
    }

    fn num_params(&self) -> usize {
        self.out_dim * self.in_dim + self.out_dim
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>> {
        let n: usize = in_shape.iter().product();
        if n != self.in_dim {
            bail!(
                "linear: input shape {in_shape:?} has {n} elements, expected {}",
                self.in_dim
            );
        }
        Ok(vec![self.out_dim])
    }

    fn forward(&self, params: &[f32], x: &HostTensor) -> Result<HostTensor> {
        let b = batch_of(x);
        let xs = x.as_f32()?;
        if per_sample_elems(x) != self.in_dim {
            bail!("linear forward: bad input shape {:?}", x.shape);
        }
        let (ind, outd) = (self.in_dim, self.out_dim);
        let w = &params[..outd * ind];
        let bias = &params[outd * ind..];
        // one [B, in] × [in, out] GEMM over bias-initialized rows
        let mut y = vec![0f32; b * outd];
        for s in 0..b {
            y[s * outd..(s + 1) * outd].copy_from_slice(bias);
        }
        gemm::sgemm_nt(b, outd, ind, xs, ind, w, ind, &mut y, outd);
        Ok(HostTensor::f32(vec![b, outd], y))
    }

    fn backward(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        gs: &mut GradSink<'_>,
        need_dx: bool,
    ) -> Result<HostTensor> {
        let b = batch_of(x);
        let xs = x.as_f32()?;
        let dys = dy.as_f32()?;
        let (ind, outd) = (self.in_dim, self.out_dim);
        let w = &params[..outd * ind];
        if gs.is_shared() {
            // summed gradient: one [out, B] × [B, in] outer-product GEMM
            let g = gs.row(0);
            gemm::sgemm_tn(outd, ind, b, dys, outd, xs, ind, &mut g[..outd * ind], ind);
            let gb = &mut g[outd * ind..];
            for s in 0..b {
                let dyr = &dys[s * outd..(s + 1) * outd];
                for o in 0..outd {
                    gb[o] += dyr[o];
                }
            }
        } else {
            // per-sample gradient rows: one rank-1 outer product each
            for s in 0..b {
                let xr = &xs[s * ind..(s + 1) * ind];
                let dyr = &dys[s * outd..(s + 1) * outd];
                let g = gs.row(s);
                outer_acc(&mut g[..outd * ind], dyr, xr, outd, ind);
                let gb = &mut g[outd * ind..];
                for o in 0..outd {
                    gb[o] += dyr[o];
                }
            }
        }
        if !need_dx {
            return Ok(HostTensor::f32(vec![b, 0], Vec::new()));
        }
        // dX[B, in] = dY[B, out] · W[out, in] in one GEMM
        let mut dx = vec![0f32; b * ind];
        gemm::sgemm(b, ind, outd, dys, outd, w, ind, &mut dx, ind);
        let mut shape = vec![b];
        shape.extend_from_slice(&x.shape[1..]);
        Ok(HostTensor::f32(shape, dx))
    }

    fn init(&self, params: &mut [f32], rng: &mut dyn Rng) {
        let nw = self.out_dim * self.in_dim;
        gaussian::fill_standard_normal(rng, &mut params[..nw]);
        let scale = (2.0 / self.in_dim as f64).sqrt() as f32;
        for p in params[..nw].iter_mut() {
            *p *= scale;
        }
        params[nw..].fill(0.0);
    }
}

// ---------------------------------------------------------------- Conv2d

/// 2-D convolution over NHWC inputs with square kernel, stride and
/// symmetric zero padding.
pub struct Conv2d {
    pub in_c: usize,
    pub out_c: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2d {
    pub fn new(in_c: usize, out_c: usize, k: usize, stride: usize, pad: usize) -> Self {
        Conv2d {
            in_c,
            out_c,
            k,
            stride,
            pad,
        }
    }

    fn out_hw(&self, h: usize, w: usize) -> Result<(usize, usize)> {
        let span = |n: usize| -> Result<usize> {
            let padded = n + 2 * self.pad;
            if padded < self.k {
                bail!("conv2d: input {n} smaller than kernel {} (pad {})", self.k, self.pad);
            }
            Ok((padded - self.k) / self.stride + 1)
        };
        Ok((span(h)?, span(w)?))
    }

    /// Columns of the im2col matrix: one `[ky][kx][ic]` patch per output
    /// position — the same ordering as the flat weight layout, so the
    /// convolution lowers to `col · Wᵀ` on the shared GEMM engine.
    fn col_width(&self) -> usize {
        self.k * self.k * self.in_c
    }

    /// im2col of one sample: `col[oh·ow, k·k·ic]` with out-of-image taps
    /// left at zero (`col` is fully overwritten).
    fn im2col(&self, xr: &[f32], h: usize, w: usize, oh: usize, ow: usize, col: &mut [f32]) {
        let (ic, k, s, p) = (self.in_c, self.k, self.stride, self.pad);
        let cw = self.col_width();
        col.fill(0.0);
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut col[(oy * ow + ox) * cw..(oy * ow + ox + 1) * cw];
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - p as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - p as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let xbase = (iy as usize * w + ix as usize) * ic;
                        let dbase = (ky * k + kx) * ic;
                        dst[dbase..dbase + ic].copy_from_slice(&xr[xbase..xbase + ic]);
                    }
                }
            }
        }
    }

    /// Adjoint of [`im2col`](Self::im2col): scatter-add col-space
    /// gradients back into image space (`dxr` accumulates).
    fn col2im(&self, dcol: &[f32], h: usize, w: usize, oh: usize, ow: usize, dxr: &mut [f32]) {
        let (ic, k, s, p) = (self.in_c, self.k, self.stride, self.pad);
        let cw = self.col_width();
        for oy in 0..oh {
            for ox in 0..ow {
                let src = &dcol[(oy * ow + ox) * cw..(oy * ow + ox + 1) * cw];
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - p as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - p as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let xbase = (iy as usize * w + ix as usize) * ic;
                        let sbase = (ky * k + kx) * ic;
                        for c in 0..ic {
                            dxr[xbase + c] += src[sbase + c];
                        }
                    }
                }
            }
        }
    }
}

impl GradSampleLayer for Conv2d {
    fn kind(&self) -> &'static str {
        "conv2d"
    }

    fn num_params(&self) -> usize {
        self.out_c * self.k * self.k * self.in_c + self.out_c
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>> {
        let [h, w, c] = in_shape else {
            bail!("conv2d: expected [H, W, C] input, got {in_shape:?}");
        };
        if *c != self.in_c {
            bail!("conv2d: input channels {c} != {}", self.in_c);
        }
        let (oh, ow) = self.out_hw(*h, *w)?;
        Ok(vec![oh, ow, self.out_c])
    }

    fn forward(&self, params: &[f32], x: &HostTensor) -> Result<HostTensor> {
        let b = batch_of(x);
        let &[h, w, _] = &x.shape[1..] else {
            bail!("conv2d forward: bad input shape {:?}", x.shape);
        };
        let (oh, ow) = self.out_hw(h, w)?;
        let xs = x.as_f32()?;
        let (ic, oc) = (self.in_c, self.out_c);
        let cw = self.col_width();
        let wts = &params[..oc * cw];
        let bias = &params[oc * cw..];
        // im2col lowering: per sample, y[oh·ow, oc] = col[oh·ow, cw] · Wᵀ
        let mut col = vec![0f32; oh * ow * cw];
        let mut y = vec![0f32; b * oh * ow * oc];
        for smp in 0..b {
            let xr = &xs[smp * h * w * ic..(smp + 1) * h * w * ic];
            self.im2col(xr, h, w, oh, ow, &mut col);
            let yr = &mut y[smp * oh * ow * oc..(smp + 1) * oh * ow * oc];
            for pos in 0..oh * ow {
                yr[pos * oc..(pos + 1) * oc].copy_from_slice(bias);
            }
            gemm::sgemm_nt(oh * ow, oc, cw, &col, cw, wts, cw, yr, oc);
        }
        Ok(HostTensor::f32(vec![b, oh, ow, oc], y))
    }

    fn backward(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        gs: &mut GradSink<'_>,
        need_dx: bool,
    ) -> Result<HostTensor> {
        let b = batch_of(x);
        let &[h, w, _] = &x.shape[1..] else {
            bail!("conv2d backward: bad input shape {:?}", x.shape);
        };
        let (oh, ow) = self.out_hw(h, w)?;
        let xs = x.as_f32()?;
        let dys = dy.as_f32()?;
        let (ic, oc) = (self.in_c, self.out_c);
        let cw = self.col_width();
        let wts = &params[..oc * cw];
        let nw = oc * cw;
        let mut dx = if need_dx {
            vec![0f32; b * h * w * ic]
        } else {
            Vec::new()
        };
        let mut col = vec![0f32; oh * ow * cw];
        let mut dcol = if need_dx {
            vec![0f32; oh * ow * cw]
        } else {
            Vec::new()
        };
        for smp in 0..b {
            let xr = &xs[smp * h * w * ic..(smp + 1) * h * w * ic];
            let dyr = &dys[smp * oh * ow * oc..(smp + 1) * oh * ow * oc];
            self.im2col(xr, h, w, oh, ow, &mut col);
            let g = gs.row(smp);
            // dW[oc, cw] += dyᵀ[oc, oh·ow] · col[oh·ow, cw]
            gemm::sgemm_tn(oc, cw, oh * ow, dyr, oc, &col, cw, &mut g[..nw], cw);
            for pos in 0..oh * ow {
                for o in 0..oc {
                    g[nw + o] += dyr[pos * oc + o];
                }
            }
            if need_dx {
                // dcol[oh·ow, cw] = dy[oh·ow, oc] · W[oc, cw], then the
                // col2im scatter-add back to image space
                dcol.fill(0.0);
                gemm::sgemm(oh * ow, cw, oc, dyr, oc, wts, cw, &mut dcol, cw);
                let dxr = &mut dx[smp * h * w * ic..(smp + 1) * h * w * ic];
                self.col2im(&dcol, h, w, oh, ow, dxr);
            }
        }
        if !need_dx {
            return Ok(HostTensor::f32(vec![b, 0], dx));
        }
        Ok(HostTensor::f32(vec![b, h, w, ic], dx))
    }

    fn init(&self, params: &mut [f32], rng: &mut dyn Rng) {
        let nw = self.out_c * self.k * self.k * self.in_c;
        gaussian::fill_standard_normal(rng, &mut params[..nw]);
        let fan_in = (self.k * self.k * self.in_c) as f64;
        let scale = (2.0 / fan_in).sqrt() as f32;
        for p in params[..nw].iter_mut() {
            *p *= scale;
        }
        params[nw..].fill(0.0);
    }
}

// ------------------------------------------------------------- Embedding

/// Token embedding lookup: i32 tokens `[B, T]` → `[B, T, dim]`.
pub struct Embedding {
    pub vocab: usize,
    pub dim: usize,
}

impl Embedding {
    pub fn new(vocab: usize, dim: usize) -> Self {
        Embedding { vocab, dim }
    }
}

impl GradSampleLayer for Embedding {
    fn kind(&self) -> &'static str {
        "embedding"
    }

    fn num_params(&self) -> usize {
        self.vocab * self.dim
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>> {
        let [t] = in_shape else {
            bail!("embedding: expected [T] token input, got {in_shape:?}");
        };
        Ok(vec![*t, self.dim])
    }

    fn forward(&self, params: &[f32], x: &HostTensor) -> Result<HostTensor> {
        let b = batch_of(x);
        let t = per_sample_elems(x);
        let toks = x.as_i32()?;
        let d = self.dim;
        let mut y = vec![0f32; b * t * d];
        for (pos, &tok) in toks.iter().enumerate() {
            if tok < 0 || tok as usize >= self.vocab {
                bail!("embedding: token {tok} out of range [0, {})", self.vocab);
            }
            let row = &params[tok as usize * d..(tok as usize + 1) * d];
            y[pos * d..(pos + 1) * d].copy_from_slice(row);
        }
        Ok(HostTensor::f32(vec![b, t, d], y))
    }

    fn backward(
        &self,
        _params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        gs: &mut GradSink<'_>,
        _need_dx: bool,
    ) -> Result<HostTensor> {
        let b = batch_of(x);
        let t = per_sample_elems(x);
        let toks = x.as_i32()?;
        let dys = dy.as_f32()?;
        let d = self.dim;
        for smp in 0..b {
            let g = gs.row(smp);
            for pos in 0..t {
                let tok = toks[smp * t + pos] as usize;
                let dyr = &dys[(smp * t + pos) * d..(smp * t + pos + 1) * d];
                let gr = &mut g[tok * d..(tok + 1) * d];
                for j in 0..d {
                    gr[j] += dyr[j];
                }
            }
        }
        // tokens carry no gradient regardless of need_dx
        Ok(HostTensor::f32(vec![b, 0], Vec::new()))
    }

    fn init(&self, params: &mut [f32], rng: &mut dyn Rng) {
        gaussian::fill_standard_normal(rng, params);
        for p in params.iter_mut() {
            *p *= 0.1;
        }
    }
}

// ------------------------------------------------------------- LayerNorm

/// Layer normalization over the last axis, with learnable scale and
/// shift (`gamma`, `beta`).
pub struct LayerNorm {
    pub dim: usize,
    pub eps: f64,
}

impl LayerNorm {
    pub fn new(dim: usize) -> Self {
        LayerNorm { dim, eps: 1e-5 }
    }
}

impl GradSampleLayer for LayerNorm {
    fn kind(&self) -> &'static str {
        "layernorm"
    }

    fn num_params(&self) -> usize {
        2 * self.dim
    }

    fn out_shape(&self, in_shape: &[usize]) -> Result<Vec<usize>> {
        match in_shape.last() {
            Some(&d) if d == self.dim => Ok(in_shape.to_vec()),
            other => bail!(
                "layernorm: last input axis {other:?} != normalized dim {}",
                self.dim
            ),
        }
    }

    fn forward(&self, params: &[f32], x: &HostTensor) -> Result<HostTensor> {
        let xs = x.as_f32()?;
        let d = self.dim;
        let rows = xs.len() / d;
        let gamma = &params[..d];
        let beta = &params[d..];
        let mut y = vec![0f32; xs.len()];
        for r in 0..rows {
            let xr = &xs[r * d..(r + 1) * d];
            let yr = &mut y[r * d..(r + 1) * d];
            let (mu, inv) = row_stats(xr, self.eps);
            for j in 0..d {
                let xhat = (xr[j] as f64 - mu) * inv;
                yr[j] = (xhat * gamma[j] as f64 + beta[j] as f64) as f32;
            }
        }
        Ok(HostTensor::f32(x.shape.clone(), y))
    }

    fn backward(
        &self,
        params: &[f32],
        x: &HostTensor,
        dy: &HostTensor,
        gs: &mut GradSink<'_>,
        need_dx: bool,
    ) -> Result<HostTensor> {
        let b = batch_of(x);
        let xs = x.as_f32()?;
        let dys = dy.as_f32()?;
        let d = self.dim;
        let rows_per_sample = per_sample_elems(x) / d;
        let gamma = &params[..d];
        let mut dx = if need_dx {
            vec![0f32; xs.len()]
        } else {
            Vec::new()
        };
        for smp in 0..b {
            let g = gs.row(smp);
            for rr in 0..rows_per_sample {
                let r = smp * rows_per_sample + rr;
                let xr = &xs[r * d..(r + 1) * d];
                let dyr = &dys[r * d..(r + 1) * d];
                let (mu, inv) = row_stats(xr, self.eps);
                let mut m1 = 0.0f64; // mean(dxhat)
                let mut m2 = 0.0f64; // mean(dxhat * xhat)
                for j in 0..d {
                    let xhat = (xr[j] as f64 - mu) * inv;
                    let dxhat = dyr[j] as f64 * gamma[j] as f64;
                    m1 += dxhat;
                    m2 += dxhat * xhat;
                    // per-sample parameter grads: dgamma then dbeta
                    g[j] += (dyr[j] as f64 * xhat) as f32;
                    g[d + j] += dyr[j];
                }
                if need_dx {
                    m1 /= d as f64;
                    m2 /= d as f64;
                    let dxr = &mut dx[r * d..(r + 1) * d];
                    for j in 0..d {
                        let xhat = (xr[j] as f64 - mu) * inv;
                        let dxhat = dyr[j] as f64 * gamma[j] as f64;
                        dxr[j] = (inv * (dxhat - m1 - xhat * m2)) as f32;
                    }
                }
            }
        }
        if !need_dx {
            return Ok(HostTensor::f32(vec![b, 0], dx));
        }
        Ok(HostTensor::f32(x.shape.clone(), dx))
    }

    fn init(&self, params: &mut [f32], _rng: &mut dyn Rng) {
        let d = self.dim;
        params[..d].fill(1.0);
        params[d..].fill(0.0);
    }
}

/// (mean, 1/√(var + eps)) of one normalization row, in f64.
fn row_stats(xr: &[f32], eps: f64) -> (f64, f64) {
    let n = xr.len() as f64;
    let mu = xr.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = xr.iter().map(|&v| (v as f64 - mu).powi(2)).sum::<f64>() / n;
    (mu, 1.0 / (var + eps).sqrt())
}

#[cfg(test)]
mod tests {
    use super::super::test_util::init_layer_params as init_params;
    use super::*;

    #[test]
    fn linear_forward_matches_manual() {
        let l = Linear::new(2, 2);
        // W = [[1, 2], [3, 4]], b = [0.5, -0.5]
        let params = vec![1.0, 2.0, 3.0, 4.0, 0.5, -0.5];
        let x = HostTensor::f32(vec![2, 2], vec![1.0, 1.0, 0.0, 2.0]);
        let y = l.forward(&params, &x).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[3.5, 6.5, 4.5, 7.5]);
    }

    #[test]
    fn linear_backward_per_sample_grads() {
        let l = Linear::new(2, 1);
        let params = vec![2.0, -1.0, 0.0]; // W = [2, -1], b = 0
        let x = HostTensor::f32(vec![2, 2], vec![1.0, 3.0, -2.0, 0.5]);
        let dy = HostTensor::f32(vec![2, 1], vec![1.0, 2.0]);
        let mut buf = vec![0f32; 2 * 3];
        let mut gs = GradSink::new(&mut buf, 3, 0, 3);
        let dx = l.backward(&params, &x, &dy, &mut gs, true).unwrap();
        // sample 0: dW = 1·x = [1, 3], db = 1; sample 1: dW = 2·x = [-4, 1], db = 2
        assert_eq!(buf, vec![1.0, 3.0, 1.0, -4.0, 1.0, 2.0]);
        // dx = dy · W
        assert_eq!(dx.as_f32().unwrap(), &[2.0, -1.0, 4.0, -2.0]);

        // need_dx = false: identical parameter grads, empty dx
        let mut buf2 = vec![0f32; 2 * 3];
        let mut gs2 = GradSink::new(&mut buf2, 3, 0, 3);
        let dx2 = l.backward(&params, &x, &dy, &mut gs2, false).unwrap();
        assert_eq!(buf2, buf);
        assert!(dx2.is_empty());

        // stride-0 shared sink: rows accumulate into one summed gradient
        let mut gsum = vec![0f32; 3];
        let mut shared = GradSink::new(&mut gsum, 0, 0, 3);
        l.backward(&params, &x, &dy, &mut shared, false).unwrap();
        assert_eq!(gsum, vec![1.0 - 4.0, 3.0 + 1.0, 1.0 + 2.0]);
    }

    #[test]
    fn conv2d_shapes() {
        let c = Conv2d::new(1, 8, 3, 2, 1);
        assert_eq!(c.out_shape(&[28, 28, 1]).unwrap(), vec![14, 14, 8]);
        assert!(c.out_shape(&[28, 28, 3]).is_err());
        let c = Conv2d::new(3, 4, 3, 1, 0);
        assert_eq!(c.out_shape(&[8, 8, 3]).unwrap(), vec![6, 6, 4]);
    }

    #[test]
    fn conv2d_identity_kernel_passes_through() {
        // 1x1 kernel, single channel, weight 1, bias 0: y == x
        let c = Conv2d::new(1, 1, 1, 1, 0);
        let params = vec![1.0, 0.0];
        let x = HostTensor::f32(vec![1, 2, 2, 1], vec![1.0, -2.0, 3.0, 4.0]);
        let y = c.forward(&params, &x).unwrap();
        assert_eq!(y.as_f32().unwrap(), x.as_f32().unwrap());
        // and its backward returns dy as dx with dW = Σ x·dy, db = Σ dy
        let dy = HostTensor::f32(vec![1, 2, 2, 1], vec![1.0, 1.0, 1.0, 1.0]);
        let mut buf = vec![0f32; 2];
        let mut gs = GradSink::new(&mut buf, 2, 0, 2);
        let dx = c.backward(&params, &x, &dy, &mut gs, true).unwrap();
        assert_eq!(dx.as_f32().unwrap(), &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(buf, vec![6.0, 4.0]); // Σx = 6, Σdy = 4
    }

    #[test]
    fn embedding_lookup_and_grad() {
        let e = Embedding::new(4, 2);
        let params = vec![0., 0., 1., 2., 3., 4., 5., 6.]; // rows 0..4
        let x = HostTensor::i32(vec![1, 3], vec![1, 3, 1]);
        let y = e.forward(&params, &x).unwrap();
        assert_eq!(y.as_f32().unwrap(), &[1., 2., 5., 6., 1., 2.]);
        // repeated token 1 must accumulate
        let dy = HostTensor::f32(vec![1, 3, 2], vec![1., 1., 1., 1., 1., 1.]);
        let mut buf = vec![0f32; 8];
        let mut gs = GradSink::new(&mut buf, 8, 0, 8);
        e.backward(&params, &x, &dy, &mut gs, true).unwrap();
        assert_eq!(buf, vec![0., 0., 2., 2., 0., 0., 1., 1.]);
        // out-of-range tokens are an error, not UB
        let bad = HostTensor::i32(vec![1, 1], vec![4]);
        assert!(e.forward(&params, &bad).is_err());
    }

    #[test]
    fn layernorm_normalizes_rows() {
        let ln = LayerNorm::new(4);
        let params = init_params(&ln, 0); // gamma = 1, beta = 0
        let x = HostTensor::f32(vec![1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let y = ln.forward(&params, &x).unwrap();
        let ys = y.as_f32().unwrap();
        let mean: f32 = ys.iter().sum::<f32>() / 4.0;
        let var: f32 = ys.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn layernorm_grad_orthogonal_to_constants() {
        // dx of layernorm must sum to ~0 along the normalized axis
        let ln = LayerNorm::new(4);
        let params = init_params(&ln, 0);
        let x = HostTensor::f32(vec![1, 4], vec![0.3, -1.2, 2.0, 0.7]);
        let dy = HostTensor::f32(vec![1, 4], vec![1.0, -0.5, 0.25, 2.0]);
        let mut buf = vec![0f32; 8];
        let mut gs = GradSink::new(&mut buf, 8, 0, 8);
        let dx = ln.backward(&params, &x, &dy, &mut gs, true).unwrap();
        let s: f32 = dx.as_f32().unwrap().iter().sum();
        assert!(s.abs() < 1e-5, "Σdx = {s}");
        // dbeta = dy
        assert_eq!(&buf[4..], dy.as_f32().unwrap());
    }

    #[test]
    fn init_is_deterministic() {
        let l = Linear::new(8, 4);
        assert_eq!(init_params(&l, 7), init_params(&l, 7));
        assert_ne!(init_params(&l, 7), init_params(&l, 8));
    }
}
