//! Typed step executables — the XLA backend's bridge between the
//! training loop and the AOT-compiled HLO graphs.
//!
//! [`Step`] is the untyped core (validate inputs against the manifest
//! signature, upload, execute, download). The typed wrappers expose each
//! step family with the right argument lists:
//!
//! * [`TrainStep`] — fused DP step / plain SGD step / microbatch step
//! * [`AccumStep`] + [`ApplyStep`] — the virtual-step split
//! * [`EvalStep`] — loss/accuracy
//! * [`LayerStep`] — per-layer microbenchmark graphs (Fig. 2/3/5)
//!
//! The shared output/hyperparameter types ([`HyperParams`],
//! [`DpStepOut`], [`AccumOut`]) double as the wire format of the
//! backend-agnostic step-family traits in
//! [`crate::runtime::backend`]; the trait impls for these wrappers live
//! in `runtime/backend/xla.rs`, and the native engine reimplements the
//! same semantics in pure Rust.

use anyhow::{anyhow, bail, Result};
use std::rc::Rc;

use super::artifact::{ArtifactMeta, Registry};
use super::tensor::{HostTensor, TensorData};

/// Hyperparameters passed to DP steps as runtime scalars.
#[derive(Debug, Clone, Copy)]
pub struct HyperParams {
    pub lr: f32,
    pub clip: f32,
    pub sigma: f32,
    /// Expected (logical) batch size — the DP-SGD denominator.
    pub denom: f32,
}

impl Default for HyperParams {
    fn default() -> Self {
        HyperParams {
            lr: 0.05,
            clip: 1.0,
            sigma: 1.1,
            denom: 64.0,
        }
    }
}

/// An executable step with its manifest signature.
pub struct Step {
    pub meta: ArtifactMeta,
    exe: Rc<xla::PjRtLoadedExecutable>,
}

impl Step {
    pub fn load(reg: &Registry, name: &str) -> Result<Step> {
        let meta = reg.meta(name)?.clone();
        let exe = reg.load(name)?;
        Ok(Step { meta, exe })
    }

    /// Validate + upload + execute + download.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "step {}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(self.meta.inputs.iter()) {
            if t.shape != spec.shape {
                bail!(
                    "step {} input '{}': shape {:?} != expected {:?}",
                    self.meta.name,
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
            if t.dtype_str() != spec.dtype {
                bail!(
                    "step {} input '{}': dtype {} != expected {}",
                    self.meta.name,
                    spec.name,
                    t.dtype_str(),
                    spec.dtype
                );
            }
        }
        let bufs = inputs
            .iter()
            .map(|t| t.to_buffer())
            .collect::<Result<Vec<_>>>()?;
        let out = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow!("executing {}: {e}", self.meta.name))?;
        // AOT graphs are lowered with return_tuple=True: one tuple output.
        let mut tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("downloading result of {}: {e}", self.meta.name))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("untupling result of {}: {e}", self.meta.name))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Total input bytes (live host-side buffer accounting for Table 3).
    pub fn input_bytes(&self) -> usize {
        self.meta.inputs.iter().map(|s| s.bytes()).sum()
    }

    /// Total output bytes.
    pub fn output_bytes(&self) -> usize {
        self.meta.outputs.iter().map(|s| s.bytes()).sum()
    }
}

/// Output of a DP training step.
#[derive(Debug, Clone)]
pub struct DpStepOut {
    pub params: Vec<f32>,
    pub loss: f64,
    /// Mean pre-clip per-sample gradient norm (monitoring, like Opacus's
    /// per-sample grad stats — Appendix D).
    pub snorm_mean: f64,
}

/// Fused training step (variants: dp / jaxstyle / microbatch / nodp).
pub struct TrainStep {
    pub step: Step,
}

impl TrainStep {
    pub fn load(reg: &Registry, name: &str) -> Result<TrainStep> {
        let step = Step::load(reg, name)?;
        if step.meta.kind != "train" {
            bail!("{name} is not a train step");
        }
        Ok(TrainStep { step })
    }

    pub fn batch(&self) -> usize {
        self.step.meta.batch
    }

    pub fn is_dp(&self) -> bool {
        matches!(
            self.step.meta.variant.as_str(),
            "dp" | "jaxstyle" | "microbatch"
        )
    }

    /// Run a DP-variant step: returns updated params + stats.
    #[allow(clippy::too_many_arguments)]
    pub fn dp_step(
        &self,
        params: &[f32],
        x: HostTensor,
        y: &[i32],
        mask: &[f32],
        noise: &[f32],
        hp: HyperParams,
    ) -> Result<DpStepOut> {
        let b = self.batch();
        let p = self.step.meta.num_params;
        let inputs = vec![
            HostTensor::f32(vec![p], params.to_vec()),
            x,
            HostTensor::i32(vec![b], y.to_vec()),
            HostTensor::f32(vec![b], mask.to_vec()),
            HostTensor::f32(vec![p], noise.to_vec()),
            HostTensor::scalar(hp.lr),
            HostTensor::scalar(hp.clip),
            HostTensor::scalar(hp.sigma),
            HostTensor::scalar(hp.denom),
        ];
        let mut out = self.step.run(&inputs)?;
        if out.len() != 3 {
            bail!("dp step returned {} outputs", out.len());
        }
        let snorm_mean = out[2].scalar_value()?;
        let loss = out[1].scalar_value()?;
        let params = match out.swap_remove(0).data {
            TensorData::F32(v) => v,
            _ => bail!("params output not f32"),
        };
        Ok(DpStepOut {
            params,
            loss,
            snorm_mean,
        })
    }

    /// Run a non-DP (plain SGD) step.
    pub fn nodp_step(
        &self,
        params: &[f32],
        x: HostTensor,
        y: &[i32],
        mask: &[f32],
        lr: f32,
        denom: f32,
    ) -> Result<(Vec<f32>, f64)> {
        let b = self.batch();
        let p = self.step.meta.num_params;
        let inputs = vec![
            HostTensor::f32(vec![p], params.to_vec()),
            x,
            HostTensor::i32(vec![b], y.to_vec()),
            HostTensor::f32(vec![b], mask.to_vec()),
            HostTensor::scalar(lr),
            HostTensor::scalar(denom),
        ];
        let mut out = self.step.run(&inputs)?;
        let loss = out[1].scalar_value()?;
        let params = match out.swap_remove(0).data {
            TensorData::F32(v) => v,
            _ => bail!("params output not f32"),
        };
        Ok((params, loss))
    }
}

/// Clipped-gradient accumulation (first half of a virtual step).
pub struct AccumStep {
    pub step: Step,
}

/// Output of one accumulation micro-step.
#[derive(Debug, Clone)]
pub struct AccumOut {
    pub gsum: Vec<f32>,
    pub loss_sum: f64,
    pub snorm_sum: f64,
}

impl AccumStep {
    pub fn load(reg: &Registry, name: &str) -> Result<AccumStep> {
        Ok(AccumStep {
            step: Step::load(reg, name)?,
        })
    }

    pub fn batch(&self) -> usize {
        self.step.meta.batch
    }

    pub fn run(
        &self,
        params: &[f32],
        x: HostTensor,
        y: &[i32],
        mask: &[f32],
        clip: f32,
    ) -> Result<AccumOut> {
        let b = self.batch();
        let p = self.step.meta.num_params;
        let inputs = vec![
            HostTensor::f32(vec![p], params.to_vec()),
            x,
            HostTensor::i32(vec![b], y.to_vec()),
            HostTensor::f32(vec![b], mask.to_vec()),
            HostTensor::scalar(clip),
        ];
        let mut out = self.step.run(&inputs)?;
        let snorm_sum = out[2].scalar_value()?;
        let loss_sum = out[1].scalar_value()?;
        let gsum = match out.swap_remove(0).data {
            TensorData::F32(v) => v,
            _ => bail!("gsum output not f32"),
        };
        Ok(AccumOut {
            gsum,
            loss_sum,
            snorm_sum,
        })
    }
}

/// Noisy parameter update from an accumulated gradient sum.
pub struct ApplyStep {
    pub step: Step,
}

impl ApplyStep {
    pub fn load(reg: &Registry, name: &str) -> Result<ApplyStep> {
        Ok(ApplyStep {
            step: Step::load(reg, name)?,
        })
    }

    pub fn run(
        &self,
        params: &[f32],
        gsum: &[f32],
        noise: &[f32],
        hp: HyperParams,
    ) -> Result<Vec<f32>> {
        let p = self.step.meta.num_params;
        let inputs = vec![
            HostTensor::f32(vec![p], params.to_vec()),
            HostTensor::f32(vec![p], gsum.to_vec()),
            HostTensor::f32(vec![p], noise.to_vec()),
            HostTensor::scalar(hp.lr),
            HostTensor::scalar(hp.clip),
            HostTensor::scalar(hp.sigma),
            HostTensor::scalar(hp.denom),
        ];
        let mut out = self.step.run(&inputs)?;
        match out.swap_remove(0).data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("params output not f32"),
        }
    }
}

/// Evaluation step: summed loss + correct-prediction count.
pub struct EvalStep {
    pub step: Step,
}

impl EvalStep {
    pub fn load(reg: &Registry, name: &str) -> Result<EvalStep> {
        Ok(EvalStep {
            step: Step::load(reg, name)?,
        })
    }

    pub fn batch(&self) -> usize {
        self.step.meta.batch
    }

    pub fn run(
        &self,
        params: &[f32],
        x: HostTensor,
        y: &[i32],
        mask: &[f32],
    ) -> Result<(f64, f64)> {
        let b = self.batch();
        let p = self.step.meta.num_params;
        let inputs = vec![
            HostTensor::f32(vec![p], params.to_vec()),
            x,
            HostTensor::i32(vec![b], y.to_vec()),
            HostTensor::f32(vec![b], mask.to_vec()),
        ];
        let out = self.step.run(&inputs)?;
        Ok((out[0].scalar_value()?, out[1].scalar_value()?))
    }
}

/// Per-layer microbenchmark step (Fig. 2/3/5 workloads).
pub struct LayerStep {
    pub step: Step,
}

impl LayerStep {
    pub fn load(reg: &Registry, name: &str) -> Result<LayerStep> {
        let step = Step::load(reg, name)?;
        if step.meta.kind != "layer" {
            bail!("{name} is not a layer step");
        }
        Ok(LayerStep { step })
    }

    pub fn is_dp(&self) -> bool {
        self.step.meta.variant == "dp"
    }

    /// Run with synthetic params/inputs (benchmark path).
    pub fn run_bench(&self, params: &[f32], x: HostTensor, clip: f32) -> Result<f64> {
        let p = self.step.meta.num_params;
        let out = if self.is_dp() {
            let b = self.step.meta.batch;
            self.step.run(&[
                HostTensor::f32(vec![p], params.to_vec()),
                x,
                HostTensor::f32(vec![b], vec![1.0; b]),
                HostTensor::scalar(clip),
            ])?
        } else {
            self.step
                .run(&[HostTensor::f32(vec![p], params.to_vec()), x])?
        };
        out[1].scalar_value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hyperparams_default() {
        let hp = HyperParams::default();
        assert_eq!(hp.clip, 1.0);
        assert!(hp.sigma > 0.0);
    }
}
