//! Process-wide PJRT CPU client.
//!
//! PJRT client creation is expensive (~50 ms) and the client owns the
//! device. `PjRtClient` is internally reference-counted (`Rc`), so it is
//! confined to one thread; the coordinator is single-threaded on the
//! request path by design (the testbed has one core), hence a
//! thread-local singleton. `global()` hands out cheap Rc clones.

use anyhow::{anyhow, Result};
use std::cell::RefCell;
use xla::PjRtClient;

thread_local! {
    static CLIENT: RefCell<Option<PjRtClient>> = const { RefCell::new(None) };
}

/// The shared PJRT CPU client for this thread (created on first use).
pub fn global() -> Result<PjRtClient> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT client: {e}"))?);
        }
        Ok(slot.as_ref().expect("set above").clone())
    })
}

/// Platform string, e.g. "cpu" (diagnostics / `opacus inspect`).
pub fn platform() -> Result<String> {
    Ok(global()?.platform_name())
}

/// True when a PJRT client can actually be created in this build/process.
/// False with the `xla-stub` crate linked (the default build) — used by
/// tests and `Backend::Auto` to skip the XLA path cleanly.
pub fn available() -> bool {
    global().is_ok()
}

/// Shared test helper: true when XLA is usable; otherwise prints the
/// skip note (one definition for every XLA-gated unit test).
#[cfg(test)]
pub(crate) fn available_or_skip() -> bool {
    if available() {
        true
    } else {
        eprintln!("skipping: XLA/PJRT unavailable (xla-stub build)");
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_platform() {
        if !available_or_skip() {
            return;
        }
        assert_eq!(platform().unwrap(), "cpu");
        assert!(global().unwrap().device_count() >= 1);
    }

    #[test]
    fn repeated_calls_cheap() {
        if !available_or_skip() {
            return;
        }
        // second call must not re-create the client (timing heuristic)
        let _ = global().unwrap();
        let (c, secs) = crate::util::stats::time_it(|| global().unwrap());
        assert!(secs < 0.01, "client re-created? {secs}s");
        drop(c);
    }

    #[test]
    fn unavailable_stub_reports_clear_error() {
        if available() {
            return; // real bindings linked: nothing to assert here
        }
        let err = global().err().expect("stub must error").to_string();
        assert!(err.contains("PJRT"), "{err}");
    }
}
