//! Process-wide PJRT CPU client.
//!
//! PJRT client creation is expensive (~50 ms) and the client owns the
//! device. `PjRtClient` is internally reference-counted (`Rc`), so it is
//! confined to one thread; the coordinator is single-threaded on the
//! request path by design (the testbed has one core), hence a
//! thread-local singleton. `global()` hands out cheap Rc clones.

use anyhow::{anyhow, Result};
use std::cell::RefCell;
use xla::PjRtClient;

thread_local! {
    static CLIENT: RefCell<Option<PjRtClient>> = const { RefCell::new(None) };
}

/// The shared PJRT CPU client for this thread (created on first use).
pub fn global() -> Result<PjRtClient> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT client: {e}"))?);
        }
        Ok(slot.as_ref().expect("set above").clone())
    })
}

/// Platform string, e.g. "cpu" (diagnostics / `opacus inspect`).
pub fn platform() -> Result<String> {
    Ok(global()?.platform_name())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_platform() {
        assert_eq!(platform().unwrap(), "cpu");
        assert!(global().unwrap().device_count() >= 1);
    }

    #[test]
    fn repeated_calls_cheap() {
        // second call must not re-create the client (timing heuristic)
        let _ = global().unwrap();
        let (c, secs) = crate::util::stats::time_it(|| global().unwrap());
        assert!(secs < 0.01, "client re-created? {secs}s");
        drop(c);
    }
}
