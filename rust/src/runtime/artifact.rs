//! Artifact registry: `manifest.json` parsing, HLO loading, compile cache.
//!
//! `make artifacts` (Python, build time) produces `artifacts/` with one
//! HLO-text file per step graph plus a manifest describing every input/
//! output signature. This module is the only bridge between that contract
//! and the typed Rust API: everything downstream asks the [`Registry`]
//! for a compiled executable by name.

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::npy::NpyArray;

/// dtype/shape of one executable input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn bytes(&self) -> usize {
        self.elements() * 4
    }
    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("spec missing name"))?
                .to_string(),
            dtype: j
                .get("dtype")
                .as_str()
                .ok_or_else(|| anyhow!("spec missing dtype"))?
                .to_string(),
            shape: j
                .get("shape")
                .as_arr()
                .ok_or_else(|| anyhow!("spec missing shape"))?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
        })
    }
}

/// One artifact (an AOT-compiled step graph).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,    // "train" | "layer"
    pub variant: String, // dp | nodp | jaxstyle | microbatch | accum | apply | eval | naive
    pub task: Option<String>,
    pub layer: Option<String>,
    pub batch: usize,
    pub num_params: usize,
    pub sample_input_bytes: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model-level metadata (per task).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub task: String,
    pub num_params: usize,
    pub input_shape: Vec<usize>,
    pub input_dtype: String,
    pub num_classes: usize,
    pub layer_kinds: Vec<String>,
    pub vocab: Option<usize>,
    pub init_file: String,
}

/// Golden test-vector description.
#[derive(Debug, Clone)]
pub struct GoldenMeta {
    pub task: String,
    pub step: String,
    pub batch: usize,
    pub scalars: HashMap<String, f64>,
    pub files: HashMap<String, String>,
    pub rtol: f64,
    pub atol: f64,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: HashMap<String, ArtifactMeta>,
    pub models: HashMap<String, ModelMeta>,
    pub goldens: Vec<GoldenMeta>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut artifacts = HashMap::new();
        for a in j.get("artifacts").as_arr().unwrap_or(&[]) {
            let name = a
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let meta = ArtifactMeta {
                name: name.clone(),
                file: a.get("file").as_str().unwrap_or_default().to_string(),
                kind: a.get("kind").as_str().unwrap_or_default().to_string(),
                variant: a.get("variant").as_str().unwrap_or_default().to_string(),
                task: a.get("task").as_str().map(|s| s.to_string()),
                layer: a.get("layer").as_str().map(|s| s.to_string()),
                batch: a.get("batch").as_usize().unwrap_or(0),
                num_params: a.get("num_params").as_usize().unwrap_or(0),
                sample_input_bytes: a.get("sample_input_bytes").as_usize().unwrap_or(0),
                inputs: a
                    .get("inputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: a
                    .get("outputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
            };
            artifacts.insert(name, meta);
        }

        let mut models = HashMap::new();
        if let Some(obj) = j.get("models").as_obj() {
            for (task, m) in obj {
                models.insert(
                    task.clone(),
                    ModelMeta {
                        task: task.clone(),
                        num_params: m.get("num_params").as_usize().unwrap_or(0),
                        input_shape: m
                            .get("input_shape")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                        input_dtype: m
                            .get("input_dtype")
                            .as_str()
                            .unwrap_or("f32")
                            .to_string(),
                        num_classes: m.get("num_classes").as_usize().unwrap_or(0),
                        layer_kinds: m
                            .get("layer_kinds")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|s| s.as_str().map(|x| x.to_string()))
                            .collect(),
                        vocab: m.get("vocab").as_usize().filter(|_| !m.get("vocab").is_null()),
                        init_file: m.get("init_file").as_str().unwrap_or_default().to_string(),
                    },
                );
            }
        }

        let mut goldens = Vec::new();
        for g in j.get("goldens").as_arr().unwrap_or(&[]) {
            let mut scalars = HashMap::new();
            if let Some(obj) = g.get("scalars").as_obj() {
                for (k, v) in obj {
                    scalars.insert(k.clone(), v.as_f64().unwrap_or(0.0));
                }
            }
            let mut files = HashMap::new();
            if let Some(obj) = g.get("files").as_obj() {
                for (k, v) in obj {
                    files.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
                }
            }
            goldens.push(GoldenMeta {
                task: g.get("task").as_str().unwrap_or_default().to_string(),
                step: g.get("step").as_str().unwrap_or_default().to_string(),
                batch: g.get("batch").as_usize().unwrap_or(0),
                scalars,
                files,
                rtol: g.get("rtol").as_f64().unwrap_or(1e-4),
                atol: g.get("atol").as_f64().unwrap_or(1e-5),
            });
        }

        Ok(Manifest {
            artifacts,
            models,
            goldens,
        })
    }
}

/// Timing of one compile (the Fig. 4 "JIT overhead" analogue).
#[derive(Debug, Clone, Copy)]
pub struct CompileStats {
    pub seconds: f64,
}

/// Registry: artifacts directory + manifest + compile cache.
pub struct Registry {
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    compile_log: RefCell<Vec<(String, f64)>>,
}

impl Registry {
    /// Open an artifacts directory produced by `make artifacts`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}; run `make artifacts`"))?;
        Ok(Registry {
            dir,
            manifest: Manifest::parse(&text)?,
            cache: RefCell::new(HashMap::new()),
            compile_log: RefCell::new(Vec::new()),
        })
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn model(&self, task: &str) -> Result<&ModelMeta> {
        self.manifest
            .models
            .get(task)
            .ok_or_else(|| anyhow!("unknown task '{task}'"))
    }

    /// Load the task's initial flat parameter vector.
    pub fn init_params(&self, task: &str) -> Result<Vec<f32>> {
        let meta = self.model(task)?;
        let arr = NpyArray::read(&self.dir.join(&meta.init_file))?;
        Ok(arr.as_f32()?.to_vec())
    }

    /// Compile (or fetch from cache) an artifact by name.
    ///
    /// The first call pays the PJRT compile cost — the moral equivalent of
    /// the first-epoch JIT overhead in the paper's Fig. 4; `compile_log`
    /// records it so the fig4 bench can report compile vs epoch time.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let meta = self.meta(name)?;
        let path = self.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(|e| anyhow!("loading HLO {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = super::client::global()?
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        let secs = t0.elapsed().as_secs_f64();
        self.compile_log.borrow_mut().push((name.to_string(), secs));
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// (name, seconds) for every compile performed so far.
    pub fn compile_log(&self) -> Vec<(String, f64)> {
        self.compile_log.borrow().clone()
    }

    /// Names of all artifacts, sorted (for `opacus inspect`).
    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        v.sort();
        v
    }

    /// True when the artifact exists in the manifest AND on disk.
    pub fn available(&self, name: &str) -> bool {
        self.manifest
            .artifacts
            .get(name)
            .map(|m| self.dir.join(&m.file).exists())
            .unwrap_or(false)
    }

    /// Sorted, deduplicated batch sizes of the *available* artifacts for
    /// a (task, variant) pair — e.g. `batches_for("mnist", "accum")`.
    /// This is how the coordinator discovers step batch sizes instead of
    /// hard-coding `_b64` names.
    pub fn batches_for(&self, task: &str, variant: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .manifest
            .artifacts
            .values()
            .filter(|a| a.task.as_deref() == Some(task) && a.variant == variant)
            .filter(|a| self.dir.join(&a.file).exists())
            .map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI_MANIFEST: &str = r#"{
      "version": 1,
      "models": {
        "mnist": {"num_params": 26010, "input_shape": [28, 28, 1],
                  "input_dtype": "f32", "num_classes": 10,
                  "layer_kinds": ["conv2d", "linear"], "vocab": null,
                  "init_file": "mnist_init.npy"}
      },
      "artifacts": [
        {"name": "mnist_dp_b16", "file": "mnist_dp_b16.hlo.txt",
         "kind": "train", "variant": "dp", "task": "mnist", "batch": 16,
         "num_params": 26010,
         "inputs": [{"name": "params", "dtype": "f32", "shape": [26010]},
                    {"name": "x", "dtype": "f32", "shape": [16, 28, 28, 1]}],
         "outputs": [{"name": "params", "dtype": "f32", "shape": [26010]}]}
      ],
      "goldens": [
        {"task": "mnist", "step": "dp", "batch": 16,
         "scalars": {"lr": 0.05}, "files": {"x": "golden_x.npy"},
         "rtol": 2e-4, "atol": 1e-5}
      ]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(MINI_MANIFEST).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts["mnist_dp_b16"];
        assert_eq!(a.batch, 16);
        assert_eq!(a.inputs[1].shape, vec![16, 28, 28, 1]);
        assert_eq!(a.inputs[1].elements(), 16 * 28 * 28);
        let model = &m.models["mnist"];
        assert_eq!(model.num_params, 26010);
        assert_eq!(model.layer_kinds, vec!["conv2d", "linear"]);
        assert_eq!(m.goldens[0].scalars["lr"], 0.05);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{").is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"file": "x"}]}"#).is_err());
    }

    #[test]
    fn spec_bytes() {
        let s = TensorSpec {
            name: "x".into(),
            dtype: "f32".into(),
            shape: vec![16, 10],
        };
        assert_eq!(s.bytes(), 640);
    }
}
