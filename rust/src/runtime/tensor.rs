//! Host tensors and their conversion to PJRT buffers / XLA literals.
//!
//! The coordinator's whole data model is flat little-endian buffers:
//! parameters are one `f32[P]` vector, batches are `f32[B, …]` /
//! `i32[B, T]`, hyperparameters are `f32[]` scalars. `HostTensor` is the
//! single host-side representation all of them share.

use anyhow::{anyhow, bail, Result};
use xla::{ElementType, Literal, PjRtBuffer};

use super::client;

/// Element payload of a host tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host-side tensor: shape + typed data.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        HostTensor {
            shape,
            data: TensorData::F32(data),
        }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        HostTensor {
            shape,
            data: TensorData::I32(data),
        }
    }

    /// A 0-d f32 scalar (hyperparameter inputs: lr, clip, σ, denom).
    pub fn scalar(v: f32) -> Self {
        HostTensor {
            shape: vec![],
            data: TensorData::F32(vec![v]),
        }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    /// Upload to the default PJRT device.
    pub fn to_buffer(&self) -> Result<PjRtBuffer> {
        let client = client::global()?;
        let buf = match &self.data {
            TensorData::F32(v) => client.buffer_from_host_buffer(v, &self.shape, None)?,
            TensorData::I32(v) => client.buffer_from_host_buffer(v, &self.shape, None)?,
        };
        Ok(buf)
    }

    /// Download a (non-tuple) literal into a host tensor.
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
            ElementType::S32 => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
            other => Err(anyhow!("unsupported literal element type {other:?}")),
        }
    }

    /// First element as f64 (for scalar outputs like loss).
    pub fn scalar_value(&self) -> Result<f64> {
        match &self.data {
            TensorData::F32(v) => v
                .first()
                .map(|&x| x as f64)
                .ok_or_else(|| anyhow!("empty tensor")),
            TensorData::I32(v) => v
                .first()
                .map(|&x| x as f64)
                .ok_or_else(|| anyhow!("empty tensor")),
        }
    }

    pub fn dtype_str(&self) -> &'static str {
        match &self.data {
            TensorData::F32(_) => "f32",
            TensorData::I32(_) => "i32",
        }
    }

    /// Rows `[start, end)` along the batch (first) axis, as an owned
    /// tensor — the shard extraction primitive of the distributed data
    /// plane (rows are row-major contiguous, so this is one memcpy).
    pub fn slice_rows(&self, start: usize, end: usize) -> Result<HostTensor> {
        let b = *self.shape.first().unwrap_or(&0);
        if start > end || end > b {
            bail!("slice_rows [{start}, {end}) out of range for batch {b}");
        }
        let per: usize = self.shape[1..].iter().product();
        let mut shape = vec![end - start];
        shape.extend_from_slice(&self.shape[1..]);
        Ok(match &self.data {
            TensorData::F32(v) => HostTensor::f32(shape, v[start * per..end * per].to_vec()),
            TensorData::I32(v) => HostTensor::i32(shape, v[start * per..end * per].to_vec()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.byte_len(), 24);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(t.as_i32().is_err());
        assert_eq!(t.dtype_str(), "f32");
    }

    #[test]
    fn scalar_tensor() {
        let s = HostTensor::scalar(0.5);
        assert!(s.shape.is_empty());
        assert_eq!(s.scalar_value().unwrap(), 0.5);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn slice_rows_extracts_contiguous_shards() {
        let t = HostTensor::f32(vec![4, 2], vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        let s = t.slice_rows(1, 3).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.as_f32().unwrap(), &[2., 3., 4., 5.]);
        // empty shard is legal (worker count above batch size)
        let e = t.slice_rows(4, 4).unwrap();
        assert_eq!(e.shape, vec![0, 2]);
        assert!(t.slice_rows(3, 5).is_err());
        assert!(t.slice_rows(2, 1).is_err());
        let ti = HostTensor::i32(vec![3, 1], vec![7, 8, 9]);
        assert_eq!(ti.slice_rows(0, 2).unwrap().as_i32().unwrap(), &[7, 8]);
    }

    #[test]
    fn zeros() {
        let z = HostTensor::zeros_f32(vec![4, 2]);
        assert_eq!(z.len(), 8);
        assert!(z.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn buffer_roundtrip_f32() {
        if !client::available_or_skip() {
            return;
        }
        let t = HostTensor::f32(vec![2, 2], vec![1.5, -2.0, 0.0, 7.25]);
        let buf = t.to_buffer().unwrap();
        let lit = buf.to_literal_sync().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn buffer_roundtrip_i32() {
        if !client::available_or_skip() {
            return;
        }
        let t = HostTensor::i32(vec![3], vec![-7, 0, 2_000_000]);
        let buf = t.to_buffer().unwrap();
        let lit = buf.to_literal_sync().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_buffer_roundtrip() {
        if !client::available_or_skip() {
            return;
        }
        let t = HostTensor::scalar(3.25);
        let lit = t.to_buffer().unwrap().to_literal_sync().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.scalar_value().unwrap(), 3.25);
    }
}
