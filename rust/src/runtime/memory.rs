//! Memory model of DP training — the paper's §3.2.2, Eq (1)–(3).
//!
//! The paper models one fwd+bwd pass over a batch of size b for a module
//! with L trainable parameter-bytes and per-sample feature/label/output
//! bytes C as
//!
//! ```text
//! M_non-DP = b·C + 2·L                (Eq 1)
//! M_DP     = b·C + (1 + b)·L          (Eq 2)
//! ```
//!
//! and the overhead ratio M_DP / M_non-DP has three regimes in L/C vs b
//! (Eq 3). We reproduce the predictions exactly and pair them with two
//! host-side measurements: (a) live buffer accounting from the artifact
//! signatures and (b) the process RSS high-water mark (`VmHWM`), our
//! substitute for "peak allocated CUDA memory" on this CPU testbed.

/// Predicted memory (bytes) per Eq (1)/(2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryModel {
    /// per-sample feature+label+output bytes (the paper's C)
    pub c_bytes: f64,
    /// trainable parameter bytes (the paper's L)
    pub l_bytes: f64,
    pub batch: usize,
}

impl MemoryModel {
    pub fn new(c_bytes: f64, l_bytes: f64, batch: usize) -> Self {
        MemoryModel {
            c_bytes,
            l_bytes,
            batch,
        }
    }

    /// Eq (1): M_non-DP = bC + 2L.
    pub fn non_dp(&self) -> f64 {
        self.batch as f64 * self.c_bytes + 2.0 * self.l_bytes
    }

    /// Eq (2): M_DP = bC + (1+b)L.
    pub fn dp(&self) -> f64 {
        self.batch as f64 * self.c_bytes + (1.0 + self.batch as f64) * self.l_bytes
    }

    /// Exact predicted overhead factor M_DP / M_non-DP.
    pub fn overhead(&self) -> f64 {
        self.dp() / self.non_dp()
    }

    /// The L/C ratio that selects the regime in Eq (3).
    pub fn l_over_c(&self) -> f64 {
        self.l_bytes / self.c_bytes
    }

    /// Eq (3)'s asymptotic regimes (for b ≫ 1): the paper's three cases.
    pub fn overhead_regime(&self) -> (&'static str, f64) {
        let b = self.batch as f64;
        let lc = self.l_over_c();
        if lc < 0.1 * b {
            ("L/C << b: 1 + L/C", 1.0 + lc)
        } else if lc > 10.0 * b {
            ("L/C >> b: (1+b)/2", (1.0 + b) / 2.0)
        } else {
            ("L/C ~ b: (2+b)/3", (2.0 + b) / 3.0)
        }
    }
}

/// Current process RSS high-water mark in bytes (Linux `VmHWM`).
pub fn rss_high_water_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Current process RSS in bytes (`VmRSS`).
pub fn rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_eq2_formulae() {
        let m = MemoryModel::new(100.0, 50.0, 8);
        assert_eq!(m.non_dp(), 8.0 * 100.0 + 100.0);
        assert_eq!(m.dp(), 8.0 * 100.0 + 9.0 * 50.0);
    }

    #[test]
    fn small_l_over_c_overhead_near_one() {
        // conv-like: tiny module, big activations (paper: conv L/C = 0.32)
        let m = MemoryModel::new(1_000_000.0, 320_000.0, 256);
        let f = m.overhead();
        assert!(f < 1.5, "factor={f}");
        let (regime, approx) = m.overhead_regime();
        assert!(regime.starts_with("L/C <<"));
        assert!((approx - (1.0 + 0.32)).abs() < 1e-9);
    }

    #[test]
    fn large_l_over_c_overhead_grows_with_b() {
        // embedding-like: huge module, tiny activations (paper: L/C ≈ 9901)
        let c = 1000.0;
        let l = 9901.0 * c;
        for &b in &[16usize, 64, 512] {
            let m = MemoryModel::new(c, l, b);
            let f = m.overhead();
            // approaches (1+b)/2
            let approx = (1.0 + b as f64) / 2.0;
            assert!((f - approx).abs() / approx < 0.15, "b={b}: {f} vs {approx}");
        }
    }

    #[test]
    fn overhead_monotone_in_batch() {
        let mut prev = 0.0;
        for &b in &[16usize, 32, 64, 128, 256, 512] {
            let f = MemoryModel::new(1000.0, 100_000.0, b).overhead();
            assert!(f > prev);
            prev = f;
        }
    }

    #[test]
    fn paper_embedding_row_magnitude() {
        // paper Table 3 embedding b=512: factor 334; L = 8 MB, C from
        // Table 4: ~0.808 KB... reproduce the right order of magnitude
        let l = 8.0 * 1024.0 * 1024.0;
        let c = l / 9901.0;
        let f = MemoryModel::new(c, l, 512).overhead();
        assert!(f > 200.0 && f < 520.0, "factor={f}");
    }

    #[test]
    fn rss_probes_work_on_linux() {
        let hwm = rss_high_water_bytes().unwrap();
        let rss = rss_bytes().unwrap();
        assert!(hwm >= rss);
        assert!(rss > 1024 * 1024); // a running test binary exceeds 1 MB
    }
}
