//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute from
//! the training hot path.
//!
//! * [`client`] — process-wide PJRT CPU client
//! * [`tensor`] — host tensors ⇄ PJRT buffers/literals
//! * [`artifact`] — `manifest.json` model + artifact registry/compile cache
//! * [`step`] — typed wrappers for each step signature (dp/nodp/accum/…)
//! * [`memory`] — the paper's Eq (1)–(3) memory model + host probes

pub mod artifact;
pub mod client;
pub mod memory;
pub mod step;
pub mod tensor;

pub use artifact::{ArtifactMeta, GoldenMeta, Manifest, ModelMeta, Registry};
pub use step::{EvalStep, LayerStep, TrainStep};
pub use tensor::HostTensor;
