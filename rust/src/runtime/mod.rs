//! The execution runtime: backends, artifacts, tensors, steps.
//!
//! * [`backend`] — the [`ExecutionBackend`](backend::ExecutionBackend)
//!   abstraction: XLA/PJRT artifacts or the pure-Rust native engine
//! * [`client`] — process-wide PJRT CPU client (XLA backend)
//! * [`tensor`] — host tensors ⇄ PJRT buffers/literals
//! * [`artifact`] — `manifest.json` model + artifact registry/compile cache
//! * [`step`] — typed wrappers for each AOT step signature (dp/nodp/accum/…)
//! * [`memory`] — the paper's Eq (1)–(3) memory model + host probes

pub mod artifact;
pub mod backend;
pub mod client;
pub mod memory;
pub mod step;
pub mod tensor;

pub use artifact::{ArtifactMeta, GoldenMeta, Manifest, ModelMeta, Registry};
pub use backend::{Backend, BackendKind, ExecutionBackend, TrainerSteps};
pub use step::{EvalStep, LayerStep, TrainStep};
pub use tensor::HostTensor;
