//! Observability: structured tracing, metrics, and live status.
//!
//! The subsystem has one switch — [`set_enabled`] — and four parts:
//!
//! - [`core`]: counters and log-linear histograms behind a global
//!   enabled flag. Every probe site in the hot path costs exactly one
//!   relaxed atomic load plus a predictable branch when off.
//! - [`trace`]: RAII [`span`] timers collected into per-thread lanes
//!   and exported as chrome://tracing trace-event JSON (`--trace FILE`
//!   on `train`/`serve`).
//! - [`logger`]: the structured progress logger behind `--log-format
//!   text|json`; text mode is byte-identical to the historical
//!   `println!` lines.
//! - [`status`]: atomically rewritten per-job `status.json` files that
//!   make a running `opacus serve` observable from outside the process.
//!
//! Two invariants hold everywhere instrumentation touches the trainer:
//!
//! 1. **Privacy-respecting** — spans, counters, and histograms record
//!    *where time went* and aggregate magnitudes only; no per-sample
//!    value ever reaches an exporter.
//! 2. **Determinism-preserving** — instrumentation only reads clocks.
//!    It never touches RNG state or reorders arithmetic, so ε and the
//!    final parameters are byte-identical with tracing on or off
//!    (pinned by `tests/obs.rs`).
//!
//! ```no_run
//! opacus_rs::obs::set_enabled(true);
//! {
//!     let _step = opacus_rs::obs::span("trainer", "step");
//!     // ... work ...
//! } // span recorded on drop
//! opacus_rs::obs::trace::export(std::path::Path::new("trace.json")).unwrap();
//! ```

pub mod core;
pub mod logger;
pub mod status;
pub mod trace;

pub use core::{
    count, enabled, observe, set_enabled, Histogram, Snapshot, HIST_BUCKETS, HIST_MAX_EXP,
    HIST_MIN_EXP, HIST_SUB, SNAPSHOT_VERSION,
};
pub use logger::LogFormat;
pub use status::StatusReport;
pub use trace::{span, span_dyn, Span};

/// Process-wide observability configuration, as chosen on the command
/// line. Stored so `opacus inspect` and exporters can report it.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsConfig {
    /// Span/counter/histogram collection is on.
    pub tracing: bool,
    /// Where the chrome://tracing export goes, if requested.
    pub trace_path: Option<std::path::PathBuf>,
    /// Progress-line format.
    pub log_format: LogFormat,
}

impl Default for ObsConfig {
    fn default() -> ObsConfig {
        ObsConfig {
            tracing: false,
            trace_path: None,
            log_format: LogFormat::Text,
        }
    }
}

impl ObsConfig {
    /// Make this the process-wide configuration: flips the collection
    /// flag and the logger format.
    pub fn install(&self) {
        logger::set_format(self.log_format);
        set_enabled(self.tracing);
    }
}

static CURRENT: std::sync::Mutex<Option<ObsConfig>> = std::sync::Mutex::new(None);

/// Record the installed configuration (for `opacus inspect` and tests).
pub fn set_config(cfg: ObsConfig) {
    cfg.install();
    *CURRENT.lock().expect("obs config lock") = Some(cfg);
}

/// The installed configuration, defaulting to everything-off.
pub fn config() -> ObsConfig {
    CURRENT
        .lock()
        .expect("obs config lock")
        .clone()
        .unwrap_or_default()
}

/// Drop all collected spans, counters, and histograms (the enabled
/// flag and lane identities survive). Used between runs in tests.
pub fn reset() {
    core::clear();
    trace::clear();
}
