//! Span collection and the chrome://tracing exporter.
//!
//! A [`Span`] is an RAII timer: construction stamps the start, drop
//! records one complete event into the calling thread's *lane*. Lanes
//! are per-thread append buffers behind individually-owned mutexes —
//! uncontended in steady state, so worker threads (`opacus-worker-N`),
//! intra-op GEMM helpers (`opacus-gemm-N`) and the prefetch producer
//! each trace into their own timeline without sharing a lock with the
//! consumer. [`export`] writes the whole collection as trace-event
//! JSON (the chrome://tracing / Perfetto "JSON Array Format"): one
//! `"ph": "X"` complete event per span plus one `thread_name` metadata
//! event per lane, so the viewer shows one named track per thread.
//!
//! When collection is disabled a span is a `None` — construction is
//! one relaxed atomic load and drop is a no-op branch. Lanes cap at
//! [`MAX_EVENTS_PER_LANE`] events; overflow increments a per-lane drop
//! counter that the export surfaces in `otherData` rather than silently
//! truncating.

use std::borrow::Cow;
use std::path::Path;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::fsio::write_atomic;
use crate::util::json::Json;

use super::core::enabled;

/// Identifies the producer of a trace file.
pub const TRACE_FORMAT: &str = "opacus-rs/trace";
/// Trace schema version (see `scripts/validate_obs.py`).
pub const TRACE_VERSION: u64 = 1;
/// Per-lane event cap; overflow is counted, never silently dropped.
pub const MAX_EVENTS_PER_LANE: usize = 1 << 20;

/// One completed span, in lane-local storage.
struct Event {
    name: Cow<'static, str>,
    cat: &'static str,
    start_us: u64,
    dur_us: u64,
}

/// One thread's timeline.
struct Lane {
    tid: u32,
    name: String,
    events: Mutex<Vec<Event>>,
    dropped: AtomicU64,
}

fn lanes() -> &'static Mutex<Vec<Arc<Lane>>> {
    static L: OnceLock<Mutex<Vec<Arc<Lane>>>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(Vec::new()))
}

/// The trace clock's zero point. Anchored when collection is enabled
/// (re-anchoring on a later enable only moves timestamps forward, never
/// behind an already-recorded event).
fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

/// Force the trace clock anchor to exist (called by `set_enabled`).
pub(super) fn anchor_epoch() {
    let _ = epoch();
}

/// Microseconds since the trace clock anchor.
pub fn epoch_micros() -> u64 {
    Instant::now().saturating_duration_since(epoch()).as_micros() as u64
}

thread_local! {
    static MY_LANE: std::cell::OnceCell<Arc<Lane>> = const { std::cell::OnceCell::new() };
}

fn with_my_lane(f: impl FnOnce(&Lane)) {
    MY_LANE.with(|cell| {
        let lane = cell.get_or_init(|| {
            static NEXT_TID: AtomicU32 = AtomicU32::new(1);
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let name = std::thread::current()
                .name()
                .map(|n| n.to_string())
                .unwrap_or_else(|| format!("thread-{tid}"));
            let lane = Arc::new(Lane {
                tid,
                name,
                events: Mutex::new(Vec::new()),
                dropped: AtomicU64::new(0),
            });
            lanes().lock().expect("obs lane registry lock").push(lane.clone());
            lane
        });
        f(lane);
    });
}

/// An RAII span timer: drop records one complete trace event on the
/// current thread's lane. Construct via [`span`] / [`span_dyn`]; hold
/// it in a `let _guard` for the scope being measured.
///
/// Spans only ever record *where time went* — they never carry data
/// values, so a trace is as privacy-safe as a wall clock.
pub struct Span {
    // None = collection was off at construction: drop is a no-op
    live: Option<(Instant, &'static str, Cow<'static, str>)>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((t0, cat, name)) = self.live.take() {
            let start_us = t0.saturating_duration_since(epoch()).as_micros() as u64;
            let dur_us = t0.elapsed().as_micros() as u64;
            with_my_lane(|lane| {
                let mut ev = lane.events.lock().expect("obs lane lock");
                if ev.len() < MAX_EVENTS_PER_LANE {
                    ev.push(Event {
                        name,
                        cat,
                        start_us,
                        dur_us,
                    });
                } else {
                    lane.dropped.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    }
}

/// Open a span with a static name (the common, allocation-free case).
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !enabled() {
        return Span { live: None };
    }
    Span {
        live: Some((Instant::now(), cat, Cow::Borrowed(name))),
    }
}

/// Open a span with a runtime-built name (job names, shard indices).
/// The `String` is only ever built by callers after checking
/// [`super::enabled`] themselves, or accepted as a cost when on.
#[inline]
pub fn span_dyn(cat: &'static str, name: String) -> Span {
    if !enabled() {
        return Span { live: None };
    }
    Span {
        live: Some((Instant::now(), cat, Cow::Owned(name))),
    }
}

/// Clear every lane (the registry keeps the lanes themselves so
/// long-lived threads keep their tid and name).
pub(super) fn clear() {
    let reg = lanes().lock().expect("obs lane registry lock");
    for lane in reg.iter() {
        lane.events.lock().expect("obs lane lock").clear();
        lane.dropped.store(0, Ordering::Relaxed);
    }
}

/// Total events currently buffered across all lanes.
pub fn event_count() -> usize {
    let reg = lanes().lock().expect("obs lane registry lock");
    reg.iter()
        .map(|l| l.events.lock().expect("obs lane lock").len())
        .sum()
}

/// Export the collected spans as chrome://tracing-compatible JSON
/// (atomically: tmp + rename). The file loads directly in
/// `chrome://tracing` or <https://ui.perfetto.dev>; each thread that
/// recorded at least one span appears as its own named track.
pub fn export(path: &Path) -> Result<()> {
    let mut events: Vec<Json> = Vec::new();
    let mut dropped_total = 0u64;
    {
        let reg = lanes().lock().expect("obs lane registry lock");
        for lane in reg.iter() {
            let ev = lane.events.lock().expect("obs lane lock");
            if ev.is_empty() {
                continue;
            }
            dropped_total += lane.dropped.load(Ordering::Relaxed);
            // one thread_name metadata record per lane → named tracks
            events.push(Json::obj(vec![
                ("ph", Json::str("M")),
                ("name", Json::str("thread_name")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(lane.tid as f64)),
                (
                    "args",
                    Json::obj(vec![("name", Json::str(&lane.name))]),
                ),
            ]));
            for e in ev.iter() {
                events.push(Json::obj(vec![
                    ("ph", Json::str("X")),
                    ("name", Json::str(&e.name)),
                    ("cat", Json::str(e.cat)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(lane.tid as f64)),
                    ("ts", Json::num(e.start_us as f64)),
                    ("dur", Json::num(e.dur_us as f64)),
                ]));
            }
        }
    }
    let doc = Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("format", Json::str(TRACE_FORMAT)),
                ("version", Json::num(TRACE_VERSION as f64)),
                ("dropped_events", Json::num(dropped_total as f64)),
            ]),
        ),
    ]);
    write_atomic(path, doc.to_string().as_bytes())
        .with_context(|| format!("writing trace file {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs;

    #[test]
    fn trace_export_schema_round_trips() {
        // exercises the global collector; unique span names keep the
        // assertions immune to events from concurrently running tests
        obs::set_enabled(true);
        {
            let _a = span("test", "trace_test_outer");
            let _b = span_dyn("test", "trace_test_inner".to_string());
            std::thread::Builder::new()
                .name("trace-test-worker".into())
                .spawn(|| {
                    let _c = span("test", "trace_test_thread");
                })
                .unwrap()
                .join()
                .unwrap();
        }
        let path = std::env::temp_dir().join(format!(
            "opacus_obs_trace_test_{}.json",
            std::process::id()
        ));
        export(&path).unwrap();
        obs::set_enabled(false);

        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(
            doc.get("otherData").get("format").as_str(),
            Some(TRACE_FORMAT)
        );
        let events = doc.get("traceEvents").as_arr().unwrap();
        let mut lanes_with_meta = std::collections::BTreeSet::new();
        let mut lanes_with_spans = std::collections::BTreeSet::new();
        let mut names = Vec::new();
        for e in events {
            let tid = e.get("tid").as_f64().unwrap() as u64;
            match e.get("ph").as_str().unwrap() {
                "M" => {
                    assert_eq!(e.get("name").as_str(), Some("thread_name"));
                    assert!(e.get("args").get("name").as_str().is_some());
                    lanes_with_meta.insert(tid);
                }
                "X" => {
                    assert!(e.get("ts").as_f64().is_some());
                    assert!(e.get("dur").as_f64().is_some());
                    assert!(e.get("cat").as_str().is_some());
                    lanes_with_spans.insert(tid);
                    names.push(e.get("name").as_str().unwrap().to_string());
                }
                other => panic!("unexpected phase {other}"),
            }
        }
        // every lane that recorded spans has a thread_name record
        assert!(lanes_with_spans.is_subset(&lanes_with_meta));
        for expect in ["trace_test_outer", "trace_test_inner", "trace_test_thread"] {
            assert!(names.iter().any(|n| n == expect), "missing span {expect}");
        }
    }

    #[test]
    fn disabled_spans_record_nothing() {
        if enabled() {
            return; // another test owns the global flag right now
        }
        let before = event_count();
        {
            let _s = span("test", "trace_test_disabled");
        }
        assert_eq!(event_count(), before);
    }
}
