//! The instrumentation core: the global enabled flag, named monotonic
//! counters, and log-linear-bucket histograms, all folded into a
//! versioned, mergeable [`Snapshot`].
//!
//! Cost model: every probe site first loads one relaxed atomic
//! ([`enabled`]) and branches — the *only* work the hot path pays when
//! observability is off (gated by the `gemm_kernels --check` overhead
//! gate). When on, counters and histogram records take one short-lived
//! mutex each; span events go to per-thread lanes (see
//! [`super::trace`]), so threads never contend on a shared buffer.
//!
//! Privacy: nothing here ever receives a per-sample value. Counters
//! and histograms record *timings and aggregate shapes* (batch sizes,
//! stage durations) — the exported snapshot is safe to ship alongside
//! the (already aggregate-only) metrics file.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Snapshot schema version, written into every exported snapshot.
pub const SNAPSHOT_VERSION: u64 = 1;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Whether instrumentation is collecting. The disabled fast path every
/// probe site branches on: one relaxed load, no fence, no call.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn collection on or off (process-global). Enabling anchors the
/// trace clock; see [`super::trace::epoch_micros`].
pub fn set_enabled(on: bool) {
    if on {
        super::trace::anchor_epoch();
    }
    ENABLED.store(on, Ordering::SeqCst);
}

fn counters() -> &'static Mutex<BTreeMap<&'static str, u64>> {
    static C: OnceLock<Mutex<BTreeMap<&'static str, u64>>> = OnceLock::new();
    C.get_or_init(|| Mutex::new(BTreeMap::new()))
}

fn histograms() -> &'static Mutex<BTreeMap<&'static str, Histogram>> {
    static H: OnceLock<Mutex<BTreeMap<&'static str, Histogram>>> = OnceLock::new();
    H.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Add `n` to the named monotonic counter (no-op when disabled).
#[inline]
pub fn count(name: &'static str, n: u64) {
    if !enabled() {
        return;
    }
    let mut c = counters().lock().expect("obs counter lock");
    *c.entry(name).or_insert(0) += n;
}

/// Record one value into the named log-linear histogram (no-op when
/// disabled). Values are typically durations in seconds.
#[inline]
pub fn observe(name: &'static str, value: f64) {
    if !enabled() {
        return;
    }
    let mut h = histograms().lock().expect("obs histogram lock");
    h.entry(name).or_insert_with(Histogram::new).record(value);
}

/// Clear all counters and histograms (the lane buffers are cleared by
/// [`super::reset`], which calls this).
pub(super) fn clear() {
    counters().lock().expect("obs counter lock").clear();
    histograms().lock().expect("obs histogram lock").clear();
}

// ---------------------------------------------------------------------
// Log-linear histogram
// ---------------------------------------------------------------------

/// Sub-buckets per power of two.
pub const HIST_SUB: usize = 4;
/// Smallest distinguished binary exponent; anything positive below
/// 2^MIN (including subnormals) lands in the underflow bucket.
pub const HIST_MIN_EXP: i32 = -64;
/// One past the largest distinguished exponent; anything at or above
/// 2^MAX (including +inf) lands in the overflow bucket.
pub const HIST_MAX_EXP: i32 = 64;
/// Bucket count: zero bucket + SUB per octave over the clamped range
/// + one overflow bucket. Positive values below the range clamp into
/// bucket 1 (whose lower bound is therefore 0); values at or above
/// 2^[`HIST_MAX_EXP`] land in the last bucket.
pub const HIST_BUCKETS: usize = 2 + (HIST_MAX_EXP - HIST_MIN_EXP) as usize * HIST_SUB;

/// A log-linear-bucket histogram over non-negative f64 values: a
/// dedicated zero bucket, then [`HIST_SUB`] linear sub-buckets per
/// power of two between 2^[`HIST_MIN_EXP`] and 2^[`HIST_MAX_EXP`]
/// (clamped at both ends, so 0, subnormals and +inf are all total —
/// nothing is dropped). Negative and NaN inputs are counted as
/// `invalid` and excluded from the statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    buckets: Vec<u64>,
    /// Valid (finite-or-inf, non-negative) samples recorded.
    pub count: u64,
    /// Σ of valid samples.
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    /// NaN or negative inputs (recorded nowhere else).
    pub invalid: u64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: vec![0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            invalid: 0,
        }
    }

    /// The bucket a value falls into (total over all f64 bit patterns).
    pub fn bucket_index(v: f64) -> usize {
        if v.is_nan() || v < 0.0 {
            // callers count these as invalid; index 0 is never used for
            // them (record() filters first) but keep the function total
            return 0;
        }
        if v == 0.0 {
            return 0;
        }
        // unbiased binary exponent from the bit pattern; subnormals
        // (biased exponent 0) sit below MIN_EXP and clamp to underflow
        let biased = ((v.to_bits() >> 52) & 0x7ff) as i32;
        let e = biased - 1023;
        if biased == 0 || e < HIST_MIN_EXP {
            return 1; // below-range values clamp into the first bucket
        }
        if e >= HIST_MAX_EXP {
            return HIST_BUCKETS - 1; // overflow bucket (incl. +inf)
        }
        // top log2(HIST_SUB) = 2 mantissa bits pick the linear sub-bucket
        let sub = ((v.to_bits() >> 50) & 0x3) as usize;
        1 + (e - HIST_MIN_EXP) as usize * HIST_SUB + sub
    }

    /// Inclusive-exclusive value bounds of bucket `i` (the zero bucket
    /// returns (0, 0); the overflow bucket's upper bound is +inf).
    pub fn bucket_bounds(i: usize) -> (f64, f64) {
        if i == 0 {
            return (0.0, 0.0);
        }
        if i >= HIST_BUCKETS - 1 {
            return ((2f64).powi(HIST_MAX_EXP), f64::INFINITY);
        }
        let slot = i - 1;
        let e = HIST_MIN_EXP + (slot / HIST_SUB) as i32;
        let sub = slot % HIST_SUB;
        let base = (2f64).powi(e);
        let step = base / HIST_SUB as f64;
        // the first regular bucket's lower bound is 0: positive values
        // below 2^MIN_EXP (subnormals included) clamp into it
        let lo = if i == 1 { 0.0 } else { base + sub as f64 * step };
        (lo, base + (sub + 1) as f64 * step)
    }

    pub fn record(&mut self, v: f64) {
        if v.is_nan() || v < 0.0 {
            self.invalid += 1;
            return;
        }
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.count > 0 {
            self.sum / self.count as f64
        } else {
            f64::NAN
        }
    }

    /// Bucket-wise fold of `other` into `self`. Merging is commutative
    /// and associative (counts add, min/max lattice-join), which is what
    /// lets per-run snapshots combine in any grouping.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.invalid += other.invalid;
    }

    /// Sparse export: only occupied buckets, as `[index, count]` pairs.
    pub fn to_json(&self) -> Json {
        let occupied: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Json::Arr(vec![Json::num(i as f64), Json::num(c as f64)]))
            .collect();
        let mut fields = vec![
            ("sub", Json::num(HIST_SUB as f64)),
            ("min_exp", Json::num(HIST_MIN_EXP as f64)),
            ("max_exp", Json::num(HIST_MAX_EXP as f64)),
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum)),
            ("invalid", Json::num(self.invalid as f64)),
            ("buckets", Json::Arr(occupied)),
        ];
        if self.count > 0 {
            // min/max only when defined — ±inf sentinels have no JSON form
            fields.push(("min", Json::num(self.min)));
            fields.push(("max", Json::num(self.max)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Histogram> {
        let mut h = Histogram::new();
        h.count = j.get("count").as_f64().unwrap_or(0.0) as u64;
        h.sum = j.get("sum").as_f64().unwrap_or(0.0);
        h.invalid = j.get("invalid").as_f64().unwrap_or(0.0) as u64;
        h.min = j.get("min").as_f64().unwrap_or(f64::INFINITY);
        h.max = j.get("max").as_f64().unwrap_or(f64::NEG_INFINITY);
        for pair in j.get("buckets").as_arr().unwrap_or(&[]) {
            let p = pair
                .as_arr()
                .ok_or_else(|| anyhow!("histogram json: bucket entry is not a pair"))?;
            let (i, c) = match p {
                [i, c] => (
                    i.as_usize()
                        .ok_or_else(|| anyhow!("histogram json: non-numeric bucket index"))?,
                    c.as_f64()
                        .ok_or_else(|| anyhow!("histogram json: non-numeric bucket count"))?
                        as u64,
                ),
                _ => return Err(anyhow!("histogram json: bucket entry is not a pair")),
            };
            if i >= HIST_BUCKETS {
                return Err(anyhow!("histogram json: bucket index {i} out of range"));
            }
            h.buckets[i] = c;
        }
        Ok(h)
    }
}

// ---------------------------------------------------------------------
// Snapshot
// ---------------------------------------------------------------------

/// A versioned, mergeable export of every counter and histogram —
/// what `--trace` runs merge into the metrics file under `"obs"`.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub version: u64,
    pub counters: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, Histogram>,
}

impl Snapshot {
    pub fn empty() -> Snapshot {
        Snapshot {
            version: SNAPSHOT_VERSION,
            counters: BTreeMap::new(),
            histograms: BTreeMap::new(),
        }
    }

    /// Copy the live global state.
    pub fn capture() -> Snapshot {
        let counters = counters()
            .lock()
            .expect("obs counter lock")
            .iter()
            .map(|(&k, &v)| (k.to_string(), v))
            .collect();
        let histograms = histograms()
            .lock()
            .expect("obs histogram lock")
            .iter()
            .map(|(&k, v)| (k.to_string(), v.clone()))
            .collect();
        Snapshot {
            version: SNAPSHOT_VERSION,
            counters,
            histograms,
        }
    }

    /// Fold `other` into `self`: counters add, histograms merge
    /// bucket-wise. Associative and commutative, so snapshots from
    /// separate runs (or a resumed run's halves) combine in any order.
    pub fn merge(&mut self, other: &Snapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.histograms {
            self.histograms
                .entry(k.clone())
                .or_insert_with(Histogram::new)
                .merge(v);
        }
    }

    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
            .collect();
        let hists: BTreeMap<String, Json> = self
            .histograms
            .iter()
            .map(|(k, v)| (k.clone(), v.to_json()))
            .collect();
        Json::obj(vec![
            ("version", Json::num(self.version as f64)),
            ("counters", Json::Obj(counters)),
            ("histograms", Json::Obj(hists)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Snapshot> {
        let version = j
            .get("version")
            .as_f64()
            .ok_or_else(|| anyhow!("obs snapshot: missing version"))? as u64;
        if version != SNAPSHOT_VERSION {
            return Err(anyhow!(
                "obs snapshot: version {version} unsupported (reader expects {SNAPSHOT_VERSION})"
            ));
        }
        let mut out = Snapshot::empty();
        if let Some(c) = j.get("counters").as_obj() {
            for (k, v) in c {
                out.counters.insert(
                    k.clone(),
                    v.as_f64()
                        .ok_or_else(|| anyhow!("obs snapshot: counter '{k}' is not numeric"))?
                        as u64,
                );
            }
        }
        if let Some(h) = j.get("histograms").as_obj() {
            for (k, v) in h {
                out.histograms.insert(k.clone(), Histogram::from_json(v)?);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_edge_cases_are_total() {
        let mut h = Histogram::new();
        h.record(0.0); // zero bucket
        h.record(1e-320); // subnormal → underflow bucket
        h.record(f64::MIN_POSITIVE / 4.0); // subnormal
        h.record(1e300); // huge → overflow bucket
        h.record(f64::INFINITY); // overflow bucket
        h.record(1.0);
        h.record(f64::NAN); // invalid
        h.record(-3.0); // invalid
        assert_eq!(h.count, 6);
        assert_eq!(h.invalid, 2);
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(1e-320), 1);
        assert_eq!(
            Histogram::bucket_index(f64::INFINITY),
            HIST_BUCKETS - 1
        );
        assert_eq!(Histogram::bucket_index(1e300), HIST_BUCKETS - 1);
        // the bucket totals equal the valid count
        let total: u64 = (0..HIST_BUCKETS)
            .map(|i| h.buckets[i])
            .sum();
        assert_eq!(total, h.count);
        assert_eq!(h.min, 0.0);
        assert_eq!(h.max, f64::INFINITY);
    }

    #[test]
    fn histogram_buckets_are_monotone_and_bounding() {
        // indices must be non-decreasing over increasing values, and
        // every in-range value must fall inside its bucket's bounds
        let mut prev = 0;
        let mut v = (2f64).powi(HIST_MIN_EXP) * 1.01;
        while v < (2f64).powi(HIST_MAX_EXP - 1) {
            let i = Histogram::bucket_index(v);
            assert!(i >= prev, "bucket index decreased at {v}");
            let (lo, hi) = Histogram::bucket_bounds(i);
            assert!(lo <= v && v < hi, "value {v} outside bucket {i} [{lo}, {hi})");
            prev = i;
            v *= 1.37;
        }
    }

    #[test]
    fn histogram_same_octave_sub_buckets_split() {
        // 1.0, 1.3, 1.6, 1.9 land in the four sub-buckets of octave 0
        let idx: Vec<usize> = [1.0, 1.3, 1.6, 1.9]
            .iter()
            .map(|&v| Histogram::bucket_index(v))
            .collect();
        assert_eq!(idx[1], idx[0] + 1);
        assert_eq!(idx[2], idx[0] + 2);
        assert_eq!(idx[3], idx[0] + 3);
        assert_eq!(Histogram::bucket_index(2.0), idx[0] + HIST_SUB);
    }

    #[test]
    fn histogram_json_round_trip() {
        let mut h = Histogram::new();
        for v in [0.0, 0.25, 1.5, 7.0, 1e300, f64::NAN] {
            h.record(v);
        }
        let back = Histogram::from_json(&Json::parse(&h.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, h);
        // an empty histogram round-trips without min/max fields
        let e = Histogram::new();
        let back = Histogram::from_json(&Json::parse(&e.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, e);
    }

    fn sample_snapshot(seed: u64) -> Snapshot {
        let mut s = Snapshot::empty();
        s.counters.insert("a".into(), seed);
        s.counters.insert(format!("k{seed}"), 2 * seed);
        let mut h = Histogram::new();
        // powers of two: f64 sums are exact, so merge order cannot
        // perturb a single bit and equality below is honest
        h.record(0.5 * seed as f64);
        h.record(2.0);
        h.record(0.0);
        s.histograms.insert("h".into(), h);
        let mut h2 = Histogram::new();
        h2.record(4.0 * seed as f64);
        s.histograms.insert(format!("h{seed}"), h2);
        s
    }

    #[test]
    fn snapshot_merge_is_associative() {
        let (a, b, c) = (sample_snapshot(1), sample_snapshot(2), sample_snapshot(4));
        // (a ⊕ b) ⊕ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right);
        assert_eq!(left.to_json().to_string(), right.to_json().to_string());
        // and commutative
        let mut ba = b.clone();
        ba.merge(&a);
        let mut ab = a.clone();
        ab.merge(&b);
        assert_eq!(ab, ba);
    }

    #[test]
    fn snapshot_json_round_trip_and_version_gate() {
        let s = sample_snapshot(3);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(Snapshot::from_json(&parsed).unwrap(), s);
        let future = r#"{"version": 2, "counters": {}, "histograms": {}}"#;
        assert!(Snapshot::from_json(&Json::parse(future).unwrap()).is_err());
    }

    #[test]
    fn disabled_probes_are_no_ops() {
        // counters/histograms only collect while enabled; the default
        // state is off, so these must leave no trace even if another
        // test enabled and reset collection earlier
        if enabled() {
            return; // a concurrent test owns the global flag; skip
        }
        count("core_test_disabled_counter", 7);
        observe("core_test_disabled_hist", 1.0);
        let snap = Snapshot::capture();
        assert!(!snap.counters.contains_key("core_test_disabled_counter"));
        assert!(!snap.histograms.contains_key("core_test_disabled_hist"));
    }
}
