//! Structured progress logging for `train` and `serve`.
//!
//! Every user-facing progress line goes through [`emit`] (or
//! [`emit_job`] for serve jobs). In the default `text` format the
//! message prints verbatim — byte-for-byte what the bare `println!`
//! used to produce, so shell pipelines and CI greps keep working. With
//! `--log-format json` each line becomes a single-line JSON object
//! (`ts_us`, `event`, optional `job`, `msg`) that a collector can
//! ingest without parsing free text.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::json::Json;

/// Output format for progress lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LogFormat {
    /// Plain lines, identical to the historical `println!` output.
    Text,
    /// One JSON object per line.
    Json,
}

impl LogFormat {
    pub fn parse(s: &str) -> Option<LogFormat> {
        match s {
            "text" => Some(LogFormat::Text),
            "json" => Some(LogFormat::Json),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            LogFormat::Text => "text",
            LogFormat::Json => "json",
        }
    }
}

static FORMAT: AtomicU8 = AtomicU8::new(0); // 0 = text, 1 = json

/// Set the process-wide log format (from `--log-format`).
pub fn set_format(f: LogFormat) {
    FORMAT.store(matches!(f, LogFormat::Json) as u8, Ordering::Relaxed);
}

/// The current process-wide log format.
pub fn format() -> LogFormat {
    if FORMAT.load(Ordering::Relaxed) == 0 {
        LogFormat::Text
    } else {
        LogFormat::Json
    }
}

fn emit_inner(event: &str, job: Option<usize>, msg: &str) {
    match format() {
        LogFormat::Text => println!("{msg}"),
        LogFormat::Json => {
            // multi-line messages (tables) become one object per line so
            // stdout stays strictly line-delimited JSON
            for line in msg.split('\n') {
                let mut fields = vec![
                    ("ts_us", Json::num(super::trace::epoch_micros() as f64)),
                    ("event", Json::str(event)),
                ];
                if let Some(j) = job {
                    fields.push(("job", Json::num(j as f64)));
                }
                fields.push(("msg", Json::str(line)));
                println!("{}", Json::obj(fields));
            }
        }
    }
}

/// Emit one progress line. `event` is a stable machine-readable tag
/// (`"epoch"`, `"metrics"`, ...); `msg` is the human-readable line.
pub fn emit(event: &str, msg: &str) {
    emit_inner(event, None, msg);
}

/// Emit one progress line tagged with a serve job index.
pub fn emit_job(job: usize, event: &str, msg: &str) {
    emit_inner(event, Some(job), msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_format_parses_and_round_trips() {
        assert_eq!(LogFormat::parse("text"), Some(LogFormat::Text));
        assert_eq!(LogFormat::parse("json"), Some(LogFormat::Json));
        assert_eq!(LogFormat::parse("yaml"), None);
        assert_eq!(LogFormat::Text.as_str(), "text");
        assert_eq!(LogFormat::Json.as_str(), "json");
    }
}
