//! Live per-job status files for `opacus serve`.
//!
//! The service rewrites `status_job{N}.json` atomically at every
//! quantum boundary, so an operator (or the CI validator) can watch a
//! running job from outside the process with nothing fancier than
//! `cat`. The ε field is produced by the same shortest-round-trip f64
//! writer as the metrics ledger, so it matches the engine's reported ε
//! bit for bit.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::fsio::write_atomic;
use crate::util::json::Json;

/// Identifies the producer of a status file.
pub const STATUS_FORMAT: &str = "opacus-rs/status";
/// Status schema version (see `scripts/validate_obs.py`).
pub const STATUS_VERSION: u64 = 1;

/// One job's externally visible state at a quantum boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct StatusReport {
    pub job: usize,
    pub task: String,
    /// `running` | `completed` | `exhausted` | `interrupted` | `failed`
    pub state: String,
    pub step: u64,
    pub epoch: usize,
    pub steps_per_sec: f64,
    /// Privacy spent so far (ε at the job's δ), bit-exact vs the engine.
    pub epsilon: f64,
    pub epsilon_budget: f64,
    /// Fraction of the ε budget consumed, clamped to [0, 1].
    pub budget_burn: f64,
    pub sigma: f64,
    /// Aggregate pipeline stage occupancy (compute seconds so far).
    pub compute_secs: f64,
    /// Aggregate noise/reduce stage seconds so far.
    pub reduce_secs: f64,
    /// Fault-recovery odometers (process-wide, monotonic): dead worker
    /// ranks respawned, checkpoint save attempts retried, checkpoint
    /// generations rolled back. All zero on a healthy run.
    pub worker_respawns: u64,
    pub checkpoint_retries: u64,
    pub checkpoint_rollbacks: u64,
    /// Terminal error message — present only when `state` is `failed`.
    pub error: Option<String>,
}

impl StatusReport {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("format", Json::str(STATUS_FORMAT)),
            ("version", Json::num(STATUS_VERSION as f64)),
            ("job", Json::num(self.job as f64)),
            ("task", Json::str(&self.task)),
            ("state", Json::str(&self.state)),
            ("step", Json::num(self.step as f64)),
            ("epoch", Json::num(self.epoch as f64)),
            ("steps_per_sec", Json::num(self.steps_per_sec)),
            ("epsilon", Json::num(self.epsilon)),
            ("epsilon_budget", Json::num(self.epsilon_budget)),
            ("budget_burn", Json::num(self.budget_burn)),
            ("sigma", Json::num(self.sigma)),
            ("compute_secs", Json::num(self.compute_secs)),
            ("reduce_secs", Json::num(self.reduce_secs)),
            ("worker_respawns", Json::num(self.worker_respawns as f64)),
            ("checkpoint_retries", Json::num(self.checkpoint_retries as f64)),
            ("checkpoint_rollbacks", Json::num(self.checkpoint_rollbacks as f64)),
        ];
        if let Some(e) = &self.error {
            fields.push(("error", Json::str(e)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<StatusReport> {
        let version = j
            .get("version")
            .as_f64()
            .context("status: missing version")? as u64;
        if version != STATUS_VERSION {
            anyhow::bail!("status: unsupported version {version}");
        }
        let f = |k: &str| -> Result<f64> {
            j.get(k).as_f64().with_context(|| format!("status: missing {k}"))
        };
        let s = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .as_str()
                .with_context(|| format!("status: missing {k}"))?
                .to_string())
        };
        Ok(StatusReport {
            job: f("job")? as usize,
            task: s("task")?,
            state: s("state")?,
            step: f("step")? as u64,
            epoch: f("epoch")? as usize,
            steps_per_sec: f("steps_per_sec")?,
            epsilon: f("epsilon")?,
            epsilon_budget: f("epsilon_budget")?,
            budget_burn: f("budget_burn")?,
            sigma: f("sigma")?,
            compute_secs: f("compute_secs")?,
            reduce_secs: f("reduce_secs")?,
            // recovery odometers are additive fields within version 1:
            // absent (older writer) reads as zero
            worker_respawns: j.get("worker_respawns").as_f64().unwrap_or(0.0) as u64,
            checkpoint_retries: j.get("checkpoint_retries").as_f64().unwrap_or(0.0) as u64,
            checkpoint_rollbacks: j.get("checkpoint_rollbacks").as_f64().unwrap_or(0.0) as u64,
            error: j.get("error").as_str().map(str::to_string),
        })
    }

    /// Atomically rewrite `path` (tmp + rename) — a reader never sees a
    /// torn file, only the previous or the new quantum's state.
    pub fn write(&self, path: &Path) -> Result<()> {
        write_atomic(path, self.to_json().to_string().as_bytes())
            .with_context(|| format!("writing status file {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StatusReport {
        StatusReport {
            job: 2,
            task: "mnist".into(),
            state: "running".into(),
            step: 144,
            epoch: 3,
            steps_per_sec: 17.25,
            epsilon: 1.234_567_890_123_456_7,
            epsilon_budget: 8.0,
            budget_burn: 1.234_567_890_123_456_7 / 8.0,
            sigma: 1.1,
            compute_secs: 12.5,
            reduce_secs: 0.75,
            worker_respawns: 1,
            checkpoint_retries: 2,
            checkpoint_rollbacks: 0,
            error: Some("worker 3 panicked".into()),
        }
    }

    #[test]
    fn status_json_round_trips_bitwise() {
        let s = sample();
        let parsed =
            StatusReport::from_json(&Json::parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(parsed, s);
        // ε must survive serialization with its bits intact
        assert_eq!(parsed.epsilon.to_bits(), s.epsilon.to_bits());
    }

    #[test]
    fn status_write_is_atomic_and_parseable() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("opacus_obs_status_test_{}.json", std::process::id()));
        sample().write(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("format").as_str(), Some(STATUS_FORMAT));
        assert!(StatusReport::from_json(&doc).is_ok());
        // no stray tmp file left behind
        assert!(!dir
            .join(format!("opacus_obs_status_test_{}.json.tmp", std::process::id()))
            .exists());
    }

    #[test]
    fn status_version_gate_rejects_future() {
        let mut j = sample().to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("version".into(), Json::num(99.0));
        }
        assert!(StatusReport::from_json(&j).is_err());
    }
}
