//! Noise calibration: find σ for a target (ε, δ) budget.
//!
//! Implements `make_private_with_epsilon`'s core (paper §2: "the engine
//! computes a noise level σ that yields an overall privacy budget of
//! (ε, δ)") by bisection over the noise multiplier — ε is strictly
//! decreasing in σ for fixed (q, T, δ).

use anyhow::{bail, Result};

use super::gdp;
use super::rdp;

/// Accountant family used for calibration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CalibKind {
    Rdp,
    Gdp,
}

fn eps_for_sigma(kind: CalibKind, sigma: f64, q: f64, steps: u64, delta: f64) -> f64 {
    match kind {
        CalibKind::Rdp => {
            let orders = rdp::default_orders();
            let r = rdp::compute_rdp(q, sigma, steps, &orders);
            rdp::rdp_to_epsilon(&orders, &r, delta).0
        }
        CalibKind::Gdp => gdp::eps_from_mu_delta(gdp::compute_mu(q, sigma, steps), delta),
    }
}

/// Smallest noise multiplier σ (to `tol` relative precision) such that
/// running `steps` SGM steps at sampling rate `q` stays within
/// (`target_eps`, `delta`).
pub fn get_noise_multiplier(
    kind: CalibKind,
    target_eps: f64,
    delta: f64,
    q: f64,
    steps: u64,
) -> Result<f64> {
    if target_eps <= 0.0 {
        bail!("target epsilon must be positive, got {target_eps}");
    }
    if !(0.0..=1.0).contains(&q) || q == 0.0 {
        bail!("sample rate must be in (0, 1], got {q}");
    }
    if steps == 0 {
        bail!("steps must be positive");
    }

    let mut lo = 1e-2; // σ below this is effectively no privacy
    let mut hi = 16.0;
    // grow hi until eps(hi) <= target
    while eps_for_sigma(kind, hi, q, steps, delta) > target_eps {
        hi *= 2.0;
        if hi > 1e6 {
            bail!("cannot reach ε={target_eps} at q={q}, T={steps} (need σ>1e6)");
        }
    }
    // ensure lo violates the target; otherwise even tiny σ suffices
    if eps_for_sigma(kind, lo, q, steps, delta) <= target_eps {
        return Ok(lo);
    }
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if eps_for_sigma(kind, mid, q, steps, delta) > target_eps {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_sigma_meets_target() {
        for &(eps, delta, q, t) in &[
            (3.0, 1e-5, 0.01, 2000u64),
            (1.0, 1e-5, 0.004, 5000),
            (8.0, 1e-6, 0.05, 1000),
        ] {
            let sigma = get_noise_multiplier(CalibKind::Rdp, eps, delta, q, t).unwrap();
            let achieved = eps_for_sigma(CalibKind::Rdp, sigma, q, t, delta);
            assert!(achieved <= eps * (1.0 + 1e-4), "achieved {achieved} > {eps}");
            // and it's tight: 2% less noise would blow the budget
            let achieved_less =
                eps_for_sigma(CalibKind::Rdp, sigma * 0.98, q, t, delta);
            assert!(achieved_less > eps * (1.0 - 1e-4));
        }
    }

    #[test]
    fn gdp_calibration_works_too() {
        let sigma = get_noise_multiplier(CalibKind::Gdp, 2.0, 1e-5, 0.01, 1000).unwrap();
        let achieved = eps_for_sigma(CalibKind::Gdp, sigma, 0.01, 1000, 1e-5);
        assert!(achieved <= 2.0 * (1.0 + 1e-4));
    }

    #[test]
    fn more_steps_need_more_noise() {
        let s1 = get_noise_multiplier(CalibKind::Rdp, 3.0, 1e-5, 0.01, 1000).unwrap();
        let s2 = get_noise_multiplier(CalibKind::Rdp, 3.0, 1e-5, 0.01, 10000).unwrap();
        assert!(s2 > s1);
    }

    #[test]
    fn tighter_budget_needs_more_noise() {
        let s1 = get_noise_multiplier(CalibKind::Rdp, 8.0, 1e-5, 0.01, 1000).unwrap();
        let s2 = get_noise_multiplier(CalibKind::Rdp, 1.0, 1e-5, 0.01, 1000).unwrap();
        assert!(s2 > s1);
    }

    #[test]
    fn invalid_inputs_rejected() {
        assert!(get_noise_multiplier(CalibKind::Rdp, -1.0, 1e-5, 0.01, 10).is_err());
        assert!(get_noise_multiplier(CalibKind::Rdp, 1.0, 1e-5, 0.0, 10).is_err());
        assert!(get_noise_multiplier(CalibKind::Rdp, 1.0, 1e-5, 0.01, 0).is_err());
    }
}
