//! Rényi Differential Privacy of the Sampled Gaussian Mechanism.
//!
//! Implements Mironov, Talwar & Zhang (2019), the accounting behind
//! Opacus's default `RDPAccountant` (paper §2 "Privacy accounting"):
//!
//! * `compute_rdp_single` — the RDP ε_α of ONE step of SGM with Poisson
//!   sampling rate q and noise multiplier σ. Closed binomial sum for
//!   integer α, the stable two-series expansion (Lemma 11 of the RDP
//!   paper / TF-privacy `_compute_log_a_frac`) for fractional α.
//! * `rdp_to_epsilon` — conversion to (ε, δ) using the improved bound
//!   of Balle et al. (2020), minimized over orders.
//!
//! Everything runs in log space; correctness is pinned to scipy-generated
//! reference values in the tests (≤1e-9 relative).

use super::special::{log_add, log_erfc, log_sub};

/// The default grid of Rényi orders (matches Opacus's default).
pub fn default_orders() -> Vec<f64> {
    let mut orders: Vec<f64> = (1..100).map(|x| 1.0 + x as f64 / 10.0).collect();
    orders.extend((12..64).map(|x| x as f64));
    orders
}

/// RDP of one SGM step at Rényi order `alpha` (> 1).
///
/// `q` is the Poisson sampling rate, `sigma` the noise multiplier
/// (noise stddev / clipping norm).
pub fn compute_rdp_single(q: f64, sigma: f64, alpha: f64) -> f64 {
    assert!(q >= 0.0 && q <= 1.0, "sampling rate out of range: {q}");
    assert!(sigma > 0.0, "noise multiplier must be positive");
    assert!(alpha > 1.0, "Rényi order must exceed 1");
    if q == 0.0 {
        return 0.0;
    }
    if (q - 1.0).abs() < 1e-15 {
        // plain Gaussian mechanism
        return alpha / (2.0 * sigma * sigma);
    }
    if alpha.fract() == 0.0 {
        log_a_int(q, sigma, alpha as u64) / (alpha - 1.0)
    } else {
        log_a_frac(q, sigma, alpha) / (alpha - 1.0)
    }
}

/// RDP vector over a grid of orders for `steps` compositions.
pub fn compute_rdp(q: f64, sigma: f64, steps: u64, orders: &[f64]) -> Vec<f64> {
    orders
        .iter()
        .map(|&a| steps as f64 * compute_rdp_single(q, sigma, a))
        .collect()
}

/// log A_α for integer α: log Σ_{i=0}^{α} C(α,i) q^i (1-q)^{α-i} e^{(i²-i)/2σ²}.
fn log_a_int(q: f64, sigma: f64, alpha: u64) -> f64 {
    let log_q = q.ln();
    let log_1q = (-q).ln_1p(); // ln(1−q), exact for small q
    let mut log_a = f64::NEG_INFINITY;
    // running log C(α,i): log C(α,i+1) = log C(α,i) + ln(α-i) - ln(i+1)
    let mut log_binom = 0.0f64;
    for i in 0..=alpha {
        let fi = i as f64;
        let s = log_binom
            + fi * log_q
            + (alpha - i) as f64 * log_1q
            + (fi * fi - fi) / (2.0 * sigma * sigma);
        log_a = log_add(log_a, s);
        if i < alpha {
            log_binom += ((alpha - i) as f64).ln() - (fi + 1.0).ln();
        }
    }
    log_a
}

/// log A_α for fractional α via the two-series expansion around
/// z0 = σ²·ln(1/q − 1) + 1/2 (TF-privacy `_compute_log_a_frac`).
fn log_a_frac(q: f64, sigma: f64, alpha: f64) -> f64 {
    let (mut log_a0, mut log_a1) = (f64::NEG_INFINITY, f64::NEG_INFINITY);
    let z0 = sigma * sigma * (1.0 / q - 1.0).ln() + 0.5;
    let log_q = q.ln();
    let log_1q = (-q).ln_1p(); // ln(1−q), exact for small q (matches log_a_int)
    let sq2s = std::f64::consts::SQRT_2 * sigma;

    // binom(α, i) tracked as (sign, log|·|), updated multiplicatively
    let mut sign = 1.0f64;
    let mut log_binom = 0.0f64;
    let mut i = 0u64;
    loop {
        let fi = i as f64;
        let j = alpha - fi;
        let log_t0 = log_binom + fi * log_q + j * log_1q;
        let log_t1 = log_binom + j * log_q + fi * log_1q;
        let log_e0 = 0.5f64.ln() + log_erfc((fi - z0) / sq2s);
        let log_e1 = 0.5f64.ln() + log_erfc((z0 - j) / sq2s);
        let log_s0 = log_t0 + (fi * fi - fi) / (2.0 * sigma * sigma) + log_e0;
        let log_s1 = log_t1 + (j * j - j) / (2.0 * sigma * sigma) + log_e1;
        if sign > 0.0 {
            log_a0 = log_add(log_a0, log_s0);
            log_a1 = log_add(log_a1, log_s1);
        } else {
            log_a0 = log_sub(log_a0, log_s0);
            log_a1 = log_sub(log_a1, log_s1);
        }
        if log_s0.max(log_s1) < -30.0 {
            break;
        }
        // update binom(α, i) -> binom(α, i+1): multiply by (α−i)/(i+1)
        let factor = alpha - fi;
        if factor < 0.0 {
            sign = -sign;
        }
        log_binom += factor.abs().max(1e-300).ln() - (fi + 1.0).ln();
        i += 1;
        if i > 10_000 {
            break; // safety net; never reached for sane (q, σ, α)
        }
    }
    log_add(log_a0, log_a1)
}

/// Convert composed RDP to (ε, δ): improved conversion (Balle et al.),
/// ε = min_α [ rdp_α − (ln δ + ln α)/(α−1) + ln((α−1)/α) ].
///
/// Returns `(epsilon, best_order)`. The minimum is taken over the *raw*
/// candidates and only the final value is clamped at 0: clamping each
/// candidate first (the pre-PR-4 behavior) yields the same ε — `max(0, ·)`
/// commutes with `min` — but lets whichever order happens to be scanned
/// first among the ≤ 0 candidates win the tie at 0, reporting a
/// degenerate `best_order` that masks the order actually achieving the
/// bound (the diagnostic `opacus epsilon` prints and tests pin).
pub fn rdp_to_epsilon(orders: &[f64], rdp: &[f64], delta: f64) -> (f64, f64) {
    assert_eq!(orders.len(), rdp.len());
    assert!(delta > 0.0 && delta < 1.0);
    let mut best = (f64::INFINITY, 0.0);
    for (&a, &r) in orders.iter().zip(rdp.iter()) {
        if a <= 1.0 {
            continue;
        }
        let eps = r - (delta.ln() + a.ln()) / (a - 1.0) + ((a - 1.0) / a).ln();
        if eps < best.0 {
            best = (eps, a);
        }
    }
    (best.0.max(0.0), best.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    // scipy/TF-privacy reference values (generated at build time, see
    // DESIGN.md; regenerate with the ported python in /tmp/rdp_ref.py)
    const RDP_REF: &[(f64, f64, f64, f64)] = &[
        (0.01, 1.1, 2.0, 1.285100816051e-04),
        (0.01, 1.1, 2.5, 1.620774093308e-04),
        (0.01, 1.1, 32.0, 8.469416433676e+00),
        (0.1, 2.0, 5.0, 7.736968489796e-03),
        (0.1, 2.0, 5.5, 8.647229350974e-03),
        (1.0, 1.5, 10.0, 2.222222222222e+00),
        (0.001, 0.8, 4.0, 7.673530693707e-06),
        (0.05, 4.0, 1.5, 1.207292124360e-04),
        (0.2, 1.2, 3.7, 1.028995681276e-01),
    ];

    #[test]
    fn rdp_matches_reference() {
        for &(q, s, a, want) in RDP_REF {
            let got = compute_rdp_single(q, s, a);
            let rel = ((got - want) / want).abs();
            assert!(rel < 1e-8, "rdp({q},{s},{a}) = {got}, want {want}");
        }
    }

    const EPS_REF: &[(f64, f64, u64, f64, f64)] = &[
        (256.0 / 60000.0, 1.1, 1, 1e-5, 0.630420429),
        (256.0 / 60000.0, 1.1, 2344, 1e-5, 1.098772546),
        (0.01, 1.5, 1000, 1e-5, 1.012952767),
        (0.02, 0.8, 500, 1e-6, 6.164547279),
        (0.04, 2.0, 10000, 1e-5, 11.689217393),
    ];

    #[test]
    fn epsilon_matches_reference() {
        let orders = default_orders();
        for &(q, s, t, d, want) in EPS_REF {
            let rdp = compute_rdp(q, s, t, &orders);
            let (eps, _) = rdp_to_epsilon(&orders, &rdp, d);
            let rel = ((eps - want) / want).abs();
            assert!(rel < 1e-6, "eps(q={q},σ={s},T={t}) = {eps}, want {want}");
        }
    }

    #[test]
    fn rdp_zero_sampling_is_free() {
        assert_eq!(compute_rdp_single(0.0, 1.0, 5.0), 0.0);
    }

    #[test]
    fn rdp_full_batch_is_gaussian() {
        let got = compute_rdp_single(1.0, 2.0, 8.0);
        assert!((got - 8.0 / 8.0).abs() < 1e-12); // α/(2σ²) = 8/(2·4)
    }

    #[test]
    fn rdp_monotone_in_alpha() {
        let mut prev = 0.0;
        for a in [1.5, 2.0, 4.0, 8.0, 16.0, 32.0] {
            let r = compute_rdp_single(0.02, 1.3, a);
            assert!(r >= prev, "not monotone at α={a}");
            prev = r;
        }
    }

    #[test]
    fn rdp_decreasing_in_sigma() {
        let mut prev = f64::INFINITY;
        for s in [0.6, 0.8, 1.0, 1.5, 2.0, 4.0] {
            let r = compute_rdp_single(0.02, s, 8.0);
            assert!(r < prev, "not decreasing at σ={s}");
            prev = r;
        }
    }

    #[test]
    fn rdp_increasing_in_q() {
        let mut prev = 0.0;
        for q in [0.001, 0.01, 0.05, 0.2, 0.5, 1.0] {
            let r = compute_rdp_single(q, 1.1, 4.0);
            assert!(r > prev, "not increasing at q={q}");
            prev = r;
        }
    }

    #[test]
    fn frac_continuous_with_int() {
        // fractional path at α = k ± 1e-6 brackets the integer path
        for &(q, s) in &[(0.01, 1.1), (0.1, 2.0)] {
            let k = 6.0;
            let lo = compute_rdp_single(q, s, k - 1e-6);
            let at = compute_rdp_single(q, s, k);
            let hi = compute_rdp_single(q, s, k + 1e-6);
            assert!((lo - at).abs() < 1e-5 * at.max(1e-12), "lo={lo} at={at}");
            assert!((hi - at).abs() < 1e-5 * at.max(1e-12), "hi={hi} at={at}");
        }
    }

    /// Regression (PR 4): `log_a_frac` used `(1.0 − q).ln()` while
    /// `log_a_int` used the exact `(-q).ln_1p()`. Forming `1.0 − q` in
    /// f64 rounds at ~1.1e-16 absolute, so the old fractional log-terms
    /// carried ~1e-16 of noise in log-space — at q = 1e-12 the whole RDP
    /// signal is ln A ≈ C(α,2)q²(e^{1/σ²}−1) ≈ 1e-23, seven orders below
    /// that noise floor. With `ln_1p` both paths agree to the residual
    /// log-add cancellation error (~1e-4 relative); the old code misses
    /// by ~1e7×, so a 1e-2 gate pins the fix without flaking.
    #[test]
    fn frac_continuous_with_int_at_tiny_q() {
        let (q, s) = (1e-12, 1.1);
        for k in [6.0, 9.0] {
            let lo = compute_rdp_single(q, s, k - 1e-6);
            let at = compute_rdp_single(q, s, k);
            let hi = compute_rdp_single(q, s, k + 1e-6);
            assert!(at > 0.0 && at.is_finite(), "α={k}: int path gave {at}");
            assert!(
                (lo - at).abs() < 1e-2 * at,
                "q=1e-12 α={k}: frac below {lo:.6e} vs int {at:.6e}"
            );
            assert!(
                (hi - at).abs() < 1e-2 * at,
                "q=1e-12 α={k}: frac above {hi:.6e} vs int {at:.6e}"
            );
        }
    }

    /// Satellite (PR 5): property test over a (q, σ, α) grid — the
    /// fractional-order two-series path must be continuous with the
    /// integer binomial path at every integer order, from both sides.
    /// Tolerance 1e-2 relative: the fractional series truncates at an
    /// absolute log-term cutoff, so its residual grows toward the
    /// tiny-signal corners of the grid (see
    /// `frac_continuous_with_int_at_tiny_q` for the scale analysis) —
    /// the PR-4 class of bug this pins missed by ~1e7×.
    #[test]
    fn frac_int_continuity_property_grid() {
        for &q in &[1e-4, 1e-3, 0.01, 0.1, 0.3] {
            for &sigma in &[0.7, 1.1, 2.0, 5.0] {
                let mut prev_hi = 0.0f64;
                for &k in &[2.0f64, 3.0, 5.0, 8.0, 13.0, 21.0, 32.0] {
                    let at = compute_rdp_single(q, sigma, k);
                    let lo = compute_rdp_single(q, sigma, k - 1e-6);
                    let hi = compute_rdp_single(q, sigma, k + 1e-6);
                    assert!(at.is_finite() && at > 0.0, "q={q} σ={sigma} α={k}: int {at}");
                    let tol = 1e-2 * at;
                    assert!(
                        (lo - at).abs() < tol,
                        "q={q} σ={sigma} α={k}: frac below {lo:.6e} vs int {at:.6e}"
                    );
                    assert!(
                        (hi - at).abs() < tol,
                        "q={q} σ={sigma} α={k}: frac above {hi:.6e} vs int {at:.6e}"
                    );
                    // RDP is nondecreasing in α, so the fractional
                    // samples must respect the grid ordering too
                    assert!(
                        lo <= hi + tol,
                        "q={q} σ={sigma} α={k}: frac not monotone across the integer"
                    );
                    assert!(
                        prev_hi <= lo + tol,
                        "q={q} σ={sigma} α={k}: frac not monotone between integers"
                    );
                    prev_hi = hi;
                }
            }
        }
    }

    #[test]
    fn epsilon_monotone_in_steps() {
        let orders = default_orders();
        let mut prev = 0.0;
        for t in [1u64, 10, 100, 1000, 10000] {
            let rdp = compute_rdp(0.01, 1.1, t, &orders);
            let (eps, _) = rdp_to_epsilon(&orders, &rdp, 1e-5);
            assert!(eps >= prev, "ε not monotone at T={t}");
            prev = eps;
        }
    }

    #[test]
    fn epsilon_decreasing_in_delta() {
        let orders = default_orders();
        let rdp = compute_rdp(0.01, 1.1, 500, &orders);
        let (e1, _) = rdp_to_epsilon(&orders, &rdp, 1e-7);
        let (e2, _) = rdp_to_epsilon(&orders, &rdp, 1e-5);
        let (e3, _) = rdp_to_epsilon(&orders, &rdp, 1e-3);
        assert!(e1 > e2 && e2 > e3);
    }

    /// The MNIST reference row (q = 256/60000, σ = 1.1, T = 2344,
    /// δ = 1e-5): ε ≈ 1.0988 is achieved at integer order α = 12 of the
    /// default grid. Pins `best_order` so conversion changes that keep ε
    /// but silently shift the reported order are caught.
    #[test]
    fn mnist_reference_row_best_order() {
        let orders = default_orders();
        let rdp = compute_rdp(256.0 / 60000.0, 1.1, 2344, &orders);
        let (eps, order) = rdp_to_epsilon(&orders, &rdp, 1e-5);
        assert!((eps - 1.098772546).abs() / 1.098772546 < 1e-6, "ε = {eps}");
        assert_eq!(order, 12.0, "best order drifted to α = {order}");
    }

    /// Regression (PR 4): with candidates that go negative (tiny RDP,
    /// large δ), the old per-candidate clamp let the *first* order tie
    /// at 0 and win; the true arg-min must be reported (ε itself is
    /// unchanged — max(0, ·) commutes with min).
    #[test]
    fn degenerate_orders_do_not_mask_best_order() {
        // hand-built candidates at δ = 0.5 (ln δ = −0.693):
        //   α = 2: 0.5 − 0 + ln(1/2)            = −0.193
        //   α = 4: 0.01 − 0.231 + ln(3/4)       = −0.509  ← true min
        //   α = 8: 0.2 − 0.198 + ln(7/8)        = −0.132
        let orders = [2.0, 4.0, 8.0];
        let rdp = [0.5, 0.01, 0.2];
        let (eps, order) = rdp_to_epsilon(&orders, &rdp, 0.5);
        assert_eq!(eps, 0.0, "negative minimum clamps to ε = 0");
        assert_eq!(order, 4.0, "must report the arg-min, not the first tie at 0");
    }

    #[test]
    fn default_orders_shape() {
        let o = default_orders();
        assert_eq!(o.len(), 99 + 52);
        assert!((o[0] - 1.1).abs() < 1e-12);
        assert_eq!(*o.last().unwrap(), 63.0);
    }
}
