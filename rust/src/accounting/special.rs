//! Special functions for privacy accounting: erf/erfc, log-erfc,
//! log-space add/sub, and the standard normal CDF.
//!
//! Implemented from scratch (no libm extras in the vendored std):
//! * |x| ≤ 2.5 — Taylor/Maclaurin series for erf (converges to f64
//!   precision in < 40 terms);
//! * x ≥ 2.5 — continued fraction for scaled erfcx(x) = e^{x²}·erfc(x),
//!   evaluated backward with fixed depth (Lentz-style), which also gives
//!   a catastrophe-free `log_erfc` for arguments up to the thousands —
//!   required by the fractional-α RDP series where erfc underflows.
//!
//! Accuracy is validated against scipy-generated reference values in the
//! unit tests (≈1e-13 relative).

use std::f64::consts::PI;

/// Error function via Maclaurin series (|x| ≤ 2.5 recommended).
fn erf_series(x: f64) -> f64 {
    let mut term = x;
    let mut sum = x;
    let x2 = x * x;
    let mut n = 0usize;
    while term.abs() > 1e-18 * sum.abs().max(1e-300) && n < 200 {
        n += 1;
        term *= -x2 / n as f64;
        sum += term / (2 * n + 1) as f64;
    }
    2.0 / PI.sqrt() * sum
}

/// Scaled complementary error function e^{x²}·erfc(x) for x ≥ 2.5,
/// via the continued fraction erfc(x) = e^{-x²}/√π · 1/(x + (1/2)/(x + 1/(x + (3/2)/(x + ...)))).
fn erfcx_cf(x: f64) -> f64 {
    debug_assert!(x > 0.0);
    let depth = 64;
    let mut t = x;
    for n in (1..=depth).rev() {
        t = x + (n as f64 / 2.0) / t;
    }
    1.0 / (PI.sqrt() * t)
}

/// Complementary error function, accurate over all of ℝ.
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    if x <= 2.5 {
        1.0 - erf_series(x)
    } else {
        erfcx_cf(x) * (-x * x).exp()
    }
}

/// Error function.
pub fn erf(x: f64) -> f64 {
    if x.abs() <= 2.5 {
        erf_series(x)
    } else if x > 0.0 {
        1.0 - erfc(x)
    } else {
        erfc(-x) - 1.0
    }
}

/// log(erfc(x)), stable for arbitrarily large x (where erfc underflows).
pub fn log_erfc(x: f64) -> f64 {
    if x <= 2.5 {
        erfc(x).ln()
    } else {
        -x * x + erfcx_cf(x).ln()
    }
}

/// Standard normal CDF Φ(x).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// log Φ(x), stable in the far-left tail.
pub fn log_normal_cdf(x: f64) -> f64 {
    log_erfc(-x / std::f64::consts::SQRT_2) - std::f64::consts::LN_2
}

/// log(e^a + e^b), tolerating -inf.
pub fn log_add(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// log(e^a − e^b), requires a ≥ b; returns -inf when equal.
pub fn log_sub(a: f64, b: f64) -> f64 {
    assert!(a >= b, "log_sub requires a >= b (got {a} < {b})");
    if b == f64::NEG_INFINITY {
        return a;
    }
    if a == b {
        return f64::NEG_INFINITY;
    }
    a + (-(b - a).exp()).ln_1p()
}

#[cfg(test)]
mod tests {
    use super::*;

    // reference values generated with scipy.special (see /tmp/rdp_ref.py in
    // the build log; regenerate with scipy.special.erfc / log_ndtr)
    const ERFC_REF: &[(f64, f64)] = &[
        (0.0, 1.0),
        (0.5, 4.795001221869535e-01),
        (1.0, 1.572992070502852e-01),
        (2.0, 4.677734981047266e-03),
        (3.0, 2.209049699858544e-05),
        (5.0, 1.537459794428035e-12),
        (-1.0, 1.842700792949715e+00),
        (-3.0, 1.999977909503001e+00),
    ];

    const LOG_ERFC_REF: &[(f64, f64)] = &[
        (1.0, -1.849605509933),
        (5.0, -27.200889545537),
        (10.0, -102.879889024845),
        (20.0, -403.569343334104),
        (35.0, -1229.128120752023),
    ];

    #[test]
    fn erfc_matches_scipy() {
        for &(x, want) in ERFC_REF {
            let got = erfc(x);
            let rel = ((got - want) / want).abs();
            assert!(rel < 1e-12, "erfc({x}) = {got}, want {want} (rel {rel})");
        }
    }

    #[test]
    fn log_erfc_matches_scipy() {
        for &(x, want) in LOG_ERFC_REF {
            let got = log_erfc(x);
            assert!(
                (got - want).abs() < 1e-8 * want.abs(),
                "log_erfc({x}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn log_erfc_agrees_with_direct_in_overlap() {
        for i in 0..50 {
            let x = -4.0 + 0.2 * i as f64; // up to 6.0
            let direct = erfc(x).ln();
            let stable = log_erfc(x);
            assert!(
                (direct - stable).abs() < 1e-10 * direct.abs().max(1.0),
                "x={x}: {direct} vs {stable}"
            );
        }
    }

    #[test]
    fn erfc_symmetry() {
        for &x in &[0.1, 0.7, 1.9, 3.3] {
            assert!((erfc(x) + erfc(-x) - 2.0).abs() < 1e-14);
        }
    }

    #[test]
    fn normal_cdf_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-15);
        assert!((normal_cdf(1.959963984540054) - 0.975).abs() < 1e-12);
        assert!((normal_cdf(-1.959963984540054) - 0.025).abs() < 1e-12);
        assert!(normal_cdf(10.0) <= 1.0);
        assert!(normal_cdf(-40.0) >= 0.0);
    }

    #[test]
    fn log_normal_cdf_tail() {
        // log Φ(-10) = log(erfc(10/√2)/2); scipy log_ndtr(-10) = -53.23128515051247
        let got = log_normal_cdf(-10.0);
        assert!((got - (-53.23128515051247)).abs() < 1e-7, "{got}");
    }

    #[test]
    fn log_add_sub_roundtrip() {
        let a = (3.0f64).ln();
        let b = (2.0f64).ln();
        assert!((log_add(a, b) - (5.0f64).ln()).abs() < 1e-14);
        assert!((log_sub(a, b) - (1.0f64).ln()).abs() < 1e-12);
        assert_eq!(log_add(f64::NEG_INFINITY, b), b);
        assert_eq!(log_sub(b, f64::NEG_INFINITY), b);
        assert_eq!(log_sub(b, b), f64::NEG_INFINITY);
    }

    #[test]
    #[should_panic]
    fn log_sub_requires_order() {
        log_sub(0.0, 1.0);
    }
}
