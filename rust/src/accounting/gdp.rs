//! Gaussian Differential Privacy (f-DP / CLT) accountant.
//!
//! The alternative accountant exposed through the paper's "custom privacy
//! accountants" interface. Based on Dong, Roth & Su (2019) and the
//! "Deep Learning with Gaussian Differential Privacy" CLT approximation
//! (Bu et al., 2020): T compositions of Poisson-subsampled Gaussian with
//! rate q and noise σ are ≈ μ-GDP with
//!
//! ```text
//! μ = q · √T · √(e^{1/σ²} − 1)
//! ```
//!
//! and the (ε, δ) trade-off of μ-GDP is
//!
//! ```text
//! δ(ε) = Φ(−ε/μ + μ/2) − e^ε · Φ(−ε/μ − μ/2).
//! ```
//!
//! NOTE: this is an asymptotic approximation — generally *less
//! conservative* than RDP for small q and large T; the `opacus epsilon
//! --compare` CLI prints both trajectories (one of the DESIGN.md
//! ablations).

use super::special::normal_cdf;

/// CLT parameter μ for T steps of SGM(q, σ).
pub fn compute_mu(q: f64, sigma: f64, steps: u64) -> f64 {
    assert!(sigma > 0.0);
    q * (steps as f64).sqrt() * ((1.0 / (sigma * sigma)).exp() - 1.0).sqrt()
}

/// δ achieved at privacy level ε under μ-GDP.
pub fn delta_from_eps(eps: f64, mu: f64) -> f64 {
    if mu <= 0.0 {
        return 0.0;
    }
    let d = normal_cdf(-eps / mu + mu / 2.0) - eps.exp() * normal_cdf(-eps / mu - mu / 2.0);
    d.clamp(0.0, 1.0)
}

/// Smallest ε with δ(ε) ≤ delta, by bisection (δ is decreasing in ε).
pub fn eps_from_mu_delta(mu: f64, delta: f64) -> f64 {
    assert!(delta > 0.0 && delta < 1.0);
    if mu <= 0.0 {
        return 0.0;
    }
    if delta_from_eps(0.0, mu) <= delta {
        return 0.0;
    }
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    while delta_from_eps(hi, mu) > delta {
        hi *= 2.0;
        if hi > 1e6 {
            return f64::INFINITY;
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if delta_from_eps(mid, mu) > delta {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mu_scaling() {
        // μ scales as √T and linearly in q
        let m1 = compute_mu(0.01, 1.0, 100);
        let m4 = compute_mu(0.01, 1.0, 400);
        assert!((m4 / m1 - 2.0).abs() < 1e-12);
        let mq = compute_mu(0.02, 1.0, 100);
        assert!((mq / m1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn delta_decreasing_in_eps() {
        let mu = 1.0;
        let mut prev = 1.0;
        for e in [0.0, 0.5, 1.0, 2.0, 4.0] {
            let d = delta_from_eps(e, mu);
            assert!(d <= prev + 1e-15);
            prev = d;
        }
    }

    #[test]
    fn gdp_known_point() {
        // μ = 1, ε = 0: δ = Φ(1/2) − Φ(−1/2) = erf(1/(2√2))... compute:
        let d = delta_from_eps(0.0, 1.0);
        let want = normal_cdf(0.5) - normal_cdf(-0.5);
        assert!((d - want).abs() < 1e-12);
    }

    #[test]
    fn eps_roundtrip() {
        for &mu in &[0.3, 1.0, 2.5] {
            for &delta in &[1e-5, 1e-3] {
                let eps = eps_from_mu_delta(mu, delta);
                let back = delta_from_eps(eps, mu);
                assert!(back <= delta * (1.0 + 1e-6), "mu={mu}: {back} > {delta}");
                // and slightly smaller ε would violate delta
                if eps > 1e-9 {
                    assert!(delta_from_eps(eps * 0.99, mu) > delta);
                }
            }
        }
    }

    #[test]
    fn eps_monotone_in_mu() {
        let mut prev = 0.0;
        for &mu in &[0.1, 0.5, 1.0, 2.0, 4.0] {
            let e = eps_from_mu_delta(mu, 1e-5);
            assert!(e >= prev);
            prev = e;
        }
    }

    #[test]
    fn zero_steps_free() {
        assert_eq!(compute_mu(0.01, 1.0, 0), 0.0);
        assert_eq!(eps_from_mu_delta(0.0, 1e-5), 0.0);
    }
}
