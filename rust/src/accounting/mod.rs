//! Privacy accounting (paper §2, "Privacy accounting").
//!
//! * [`rdp`] — Rényi DP of the Sampled Gaussian Mechanism (default)
//! * [`gdp`] — Gaussian-DP CLT accountant (alternative / ablation)
//! * [`accountant`] — the `Accountant` trait + implementations
//! * [`calibration`] — σ from a target (ε, δ)
//! * [`special`] — erfc / log-erfc / log-space arithmetic substrate

pub mod accountant;
pub mod calibration;
pub mod gdp;
pub mod rdp;
pub mod special;

pub use accountant::{
    make_accountant, Accountant, GdpAccountant, HistoryEntry, RdpAccountant, VALID_ACCOUNTANTS,
};
pub use calibration::{get_noise_multiplier, CalibKind};
