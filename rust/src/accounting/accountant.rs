//! The accountant interface and its two implementations.
//!
//! Mirrors Opacus's design: the `PrivacyEngine` owns an accountant, every
//! optimizer step records `(noise_multiplier, sample_rate)` into its
//! history, and `get_epsilon(delta)` can be queried at any time (enabling
//! the paper's "early stopping and real-time monitoring"). The trait is
//! public, so user-defined accountants plug in exactly like Opacus's
//! "interface to write custom privacy accountants".

use anyhow::{bail, Result};

use super::{gdp, rdp};

/// A privacy accountant: records mechanism invocations, answers ε queries.
pub trait Accountant: Send {
    /// Record `steps` invocations of SGM with the given parameters.
    fn record(&mut self, noise_multiplier: f64, sample_rate: f64, steps: u64);

    /// Privacy spent so far, as ε at the given δ.
    fn get_epsilon(&self, delta: f64) -> f64;

    /// Total steps recorded.
    fn steps(&self) -> u64;

    /// Mechanism name (for logs / validation messages).
    fn mechanism(&self) -> &'static str;

    /// The recorded history, for checkpoint serialization. Replaying
    /// these entries through [`Accountant::record`] on a fresh accountant
    /// of the same kind reproduces ε bit-for-bit: both built-in
    /// accountants compute ε purely from their history (RDP's
    /// merge-on-identical-parameters is replay-stable).
    fn history_entries(&self) -> Vec<HistoryEntry>;
}

/// History entry: a run of identical steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistoryEntry {
    pub noise_multiplier: f64,
    pub sample_rate: f64,
    pub steps: u64,
}

/// Rényi-DP accountant (Opacus's default).
#[derive(Debug, Default)]
pub struct RdpAccountant {
    history: Vec<HistoryEntry>,
    orders: Vec<f64>,
}

impl RdpAccountant {
    pub fn new() -> Self {
        RdpAccountant {
            history: Vec::new(),
            orders: rdp::default_orders(),
        }
    }

    pub fn with_orders(orders: Vec<f64>) -> Self {
        assert!(!orders.is_empty());
        RdpAccountant {
            history: Vec::new(),
            orders,
        }
    }

    pub fn history(&self) -> &[HistoryEntry] {
        &self.history
    }

    /// ε and the optimal Rényi order.
    pub fn get_epsilon_and_order(&self, delta: f64) -> (f64, f64) {
        let mut total = vec![0.0; self.orders.len()];
        for h in &self.history {
            for (t, &a) in total.iter_mut().zip(self.orders.iter()) {
                *t += h.steps as f64
                    * rdp::compute_rdp_single(h.sample_rate, h.noise_multiplier, a);
            }
        }
        rdp::rdp_to_epsilon(&self.orders, &total, delta)
    }
}

impl Accountant for RdpAccountant {
    fn record(&mut self, noise_multiplier: f64, sample_rate: f64, steps: u64) {
        if steps == 0 {
            return;
        }
        // merge with the previous entry when parameters are unchanged
        // (keeps history O(#schedule-changes), not O(#steps))
        if let Some(last) = self.history.last_mut() {
            if last.noise_multiplier == noise_multiplier && last.sample_rate == sample_rate {
                last.steps += steps;
                return;
            }
        }
        self.history.push(HistoryEntry {
            noise_multiplier,
            sample_rate,
            steps,
        });
    }

    fn get_epsilon(&self, delta: f64) -> f64 {
        if self.history.is_empty() {
            return 0.0;
        }
        self.get_epsilon_and_order(delta).0
    }

    fn steps(&self) -> u64 {
        self.history.iter().map(|h| h.steps).sum()
    }

    fn mechanism(&self) -> &'static str {
        "rdp"
    }

    fn history_entries(&self) -> Vec<HistoryEntry> {
        self.history.clone()
    }
}

/// Gaussian-DP (CLT) accountant. Composition across heterogeneous
/// segments sums μ² (valid because μ-GDP composes in quadrature).
#[derive(Debug, Default)]
pub struct GdpAccountant {
    history: Vec<HistoryEntry>,
}

impl GdpAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn total_mu(&self) -> f64 {
        self.history
            .iter()
            .map(|h| {
                let mu = gdp::compute_mu(h.sample_rate, h.noise_multiplier, h.steps);
                mu * mu
            })
            .sum::<f64>()
            .sqrt()
    }
}

impl Accountant for GdpAccountant {
    fn record(&mut self, noise_multiplier: f64, sample_rate: f64, steps: u64) {
        if steps == 0 {
            return;
        }
        self.history.push(HistoryEntry {
            noise_multiplier,
            sample_rate,
            steps,
        });
    }

    fn get_epsilon(&self, delta: f64) -> f64 {
        gdp::eps_from_mu_delta(self.total_mu(), delta)
    }

    fn steps(&self) -> u64 {
        self.history.iter().map(|h| h.steps).sum()
    }

    fn mechanism(&self) -> &'static str {
        "gdp"
    }

    fn history_entries(&self) -> Vec<HistoryEntry> {
        self.history.clone()
    }
}

/// Accountant names accepted by [`make_accountant`] (and by the CLI's
/// `--accountant` flag / `AccountantKind::from_str`).
pub const VALID_ACCOUNTANTS: &[&str] = &["rdp", "gdp"];

/// Accountant selection (CLI / config). Unknown names are an error (not a
/// panic) so the failure can surface through `PrivateBuilder::build`.
pub fn make_accountant(kind: &str) -> Result<Box<dyn Accountant>> {
    match kind {
        "rdp" => Ok(Box::new(RdpAccountant::new())),
        "gdp" => Ok(Box::new(GdpAccountant::new())),
        other => bail!(
            "unknown accountant '{other}' (valid kinds: {})",
            VALID_ACCOUNTANTS.join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_accountant_spends_nothing() {
        let acc = RdpAccountant::new();
        assert_eq!(acc.get_epsilon(1e-5), 0.0);
        assert_eq!(acc.steps(), 0);
    }

    #[test]
    fn history_merges_identical_segments() {
        let mut acc = RdpAccountant::new();
        acc.record(1.1, 0.01, 100);
        acc.record(1.1, 0.01, 50);
        acc.record(1.2, 0.01, 10);
        assert_eq!(acc.history().len(), 2);
        assert_eq!(acc.steps(), 160);
    }

    #[test]
    fn merged_equals_split_epsilon() {
        let mut a = RdpAccountant::new();
        a.record(1.1, 0.02, 300);
        let mut b = RdpAccountant::new();
        b.record(1.1, 0.02, 100);
        b.record(1.1, 0.02, 200);
        assert!((a.get_epsilon(1e-5) - b.get_epsilon(1e-5)).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_composition_adds_up() {
        // mixed-σ history must cost more than either segment alone
        let mut acc = RdpAccountant::new();
        acc.record(2.0, 0.01, 500);
        let e1 = acc.get_epsilon(1e-5);
        acc.record(1.0, 0.01, 500);
        let e2 = acc.get_epsilon(1e-5);
        assert!(e2 > e1);
    }

    #[test]
    fn rdp_matches_direct_computation() {
        let mut acc = RdpAccountant::new();
        acc.record(1.5, 0.01, 1000);
        let orders = rdp::default_orders();
        let r = rdp::compute_rdp(0.01, 1.5, 1000, &orders);
        let (want, _) = rdp::rdp_to_epsilon(&orders, &r, 1e-5);
        assert!((acc.get_epsilon(1e-5) - want).abs() < 1e-12);
    }

    #[test]
    fn gdp_less_conservative_than_rdp_here() {
        // For small q and many steps the CLT bound is tighter (one reason
        // Opacus defaults to RDP: it is a *guarantee*, not an asymptotic)
        let mut r = RdpAccountant::new();
        let mut g = GdpAccountant::new();
        r.record(1.1, 0.004, 5000);
        g.record(1.1, 0.004, 5000);
        assert!(g.get_epsilon(1e-5) < r.get_epsilon(1e-5));
    }

    #[test]
    fn gdp_quadrature_composition() {
        let mut a = GdpAccountant::new();
        a.record(1.0, 0.01, 100);
        a.record(1.0, 0.01, 100);
        let mut b = GdpAccountant::new();
        b.record(1.0, 0.01, 200);
        assert!((a.total_mu() - b.total_mu()).abs() < 1e-12);
    }

    #[test]
    fn factory() {
        assert_eq!(make_accountant("rdp").unwrap().mechanism(), "rdp");
        assert_eq!(make_accountant("gdp").unwrap().mechanism(), "gdp");
        assert!(make_accountant("prv").is_err());
    }

    #[test]
    fn factory_error_lists_valid_kinds() {
        let err = make_accountant("prv")
            .err()
            .expect("unknown accountant must be an error")
            .to_string();
        assert!(err.contains("prv"), "error should name the bad kind: {err}");
        for kind in VALID_ACCOUNTANTS {
            assert!(err.contains(kind), "error should list '{kind}': {err}");
        }
    }

    #[test]
    fn history_replay_is_epsilon_exact() {
        // serialize → replay into a fresh accountant → ε bit-identical
        for kind in VALID_ACCOUNTANTS {
            let mut a = make_accountant(kind).unwrap();
            a.record(1.1, 0.01, 120);
            a.record(1.1, 0.01, 40); // merge path (rdp)
            a.record(0.9, 0.02, 77); // schedule change
            let mut b = make_accountant(kind).unwrap();
            for h in a.history_entries() {
                b.record(h.noise_multiplier, h.sample_rate, h.steps);
            }
            assert_eq!(a.steps(), b.steps());
            for delta in [1e-5, 1e-6] {
                assert_eq!(
                    a.get_epsilon(delta).to_bits(),
                    b.get_epsilon(delta).to_bits(),
                    "{kind} replay must be bit-exact at δ={delta}"
                );
            }
        }
    }

    #[test]
    fn zero_steps_noop() {
        let mut acc = RdpAccountant::new();
        acc.record(1.1, 0.01, 0);
        assert!(acc.history().is_empty());
    }
}
