//! Integration tests over real AOT artifacts (require `make artifacts`).
//!
//! The golden tests are the cross-language correctness anchor: aot.py
//! executed each step in JAX with fixed inputs and saved the outputs;
//! here the PJRT-compiled HLO must reproduce them from Rust.
//!
//! The legacy `make_private(sys, pp)` shims are deprecated in favour of
//! the `PrivateBuilder`; their tests stay on purpose (the shim must keep
//! passing), hence the file-wide allow.
#![allow(deprecated)]

use std::path::PathBuf;

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{
    AccountantKind, ClippingStrategy, EngineConfig, PrivacyEngine, PrivacyParams, SamplingMode,
};
use opacus_rs::runtime::artifact::Registry;
use opacus_rs::runtime::step::{AccumStep, ApplyStep, EvalStep, HyperParams, TrainStep};
use opacus_rs::runtime::tensor::HostTensor;
use opacus_rs::util::npy::NpyArray;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn load_npy(dir: &std::path::Path, file: &str) -> NpyArray {
    NpyArray::read(&dir.join(file)).unwrap_or_else(|e| panic!("loading {file}: {e}"))
}

fn assert_close(got: &[f32], want: &[f32], rtol: f64, atol: f64, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    let mut worst = 0.0f64;
    let mut worst_i = 0;
    for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
        let err = (g as f64 - w as f64).abs();
        let bound = atol + rtol * (w as f64).abs();
        if err - bound > worst {
            worst = err - bound;
            worst_i = i;
        }
    }
    assert!(
        worst <= 0.0,
        "{what}: worst mismatch at {worst_i}: got {} want {} (excess {worst:.3e})",
        got[worst_i],
        want[worst_i]
    );
}

/// Run every dp-step golden: Rust PJRT execution must match JAX outputs.
#[test]
fn golden_dp_steps_match_jax() {
    let dir = require_artifacts!();
    let reg = Registry::open(&dir).unwrap();
    let goldens: Vec<_> = reg
        .manifest
        .goldens
        .iter()
        .filter(|g| g.step == "dp")
        .cloned()
        .collect();
    assert_eq!(goldens.len(), 4, "expected one dp golden per task");
    for g in goldens {
        let name = format!("{}_dp_b{}", g.task, g.batch);
        let step = TrainStep::load(&reg, &name).unwrap();
        let params = load_npy(&dir, &g.files["params"]);
        let x_arr = load_npy(&dir, &g.files["x"]);
        let y = load_npy(&dir, &g.files["y"]);
        let mask = load_npy(&dir, &g.files["mask"]);
        let noise = load_npy(&dir, &g.files["noise"]);
        let want_params = load_npy(&dir, &g.files["out_params"]);
        let want_loss = load_npy(&dir, &g.files["out_loss"]);
        let want_snorm = load_npy(&dir, &g.files["out_snorm"]);

        let x = match &x_arr.data {
            opacus_rs::util::npy::NpyData::F32(v) => {
                HostTensor::f32(x_arr.shape.clone(), v.clone())
            }
            opacus_rs::util::npy::NpyData::I32(v) => {
                HostTensor::i32(x_arr.shape.clone(), v.clone())
            }
            _ => panic!("unexpected x dtype"),
        };
        let hp = HyperParams {
            lr: g.scalars["lr"] as f32,
            clip: g.scalars["clip"] as f32,
            sigma: g.scalars["sigma"] as f32,
            denom: g.scalars["denom"] as f32,
        };
        let out = step
            .dp_step(
                params.as_f32().unwrap(),
                x,
                y.as_i32().unwrap(),
                mask.as_f32().unwrap(),
                noise.as_f32().unwrap(),
                hp,
            )
            .unwrap();
        assert_close(
            &out.params,
            want_params.as_f32().unwrap(),
            g.rtol,
            g.atol,
            &format!("{name} params"),
        );
        let wl = want_loss.as_f32().unwrap()[0] as f64;
        assert!(
            (out.loss - wl).abs() < 1e-4 * wl.abs().max(1.0),
            "{name} loss: {} vs {wl}",
            out.loss
        );
        let ws = want_snorm.as_f32().unwrap()[0] as f64;
        assert!(
            (out.snorm_mean - ws).abs() < 1e-3 * ws.abs().max(1.0),
            "{name} snorm: {} vs {ws}",
            out.snorm_mean
        );
    }
}

/// Eval goldens: loss sums and correct counts match JAX.
#[test]
fn golden_eval_steps_match_jax() {
    let dir = require_artifacts!();
    let reg = Registry::open(&dir).unwrap();
    for g in reg.manifest.goldens.iter().filter(|g| g.step == "eval") {
        let name = format!("{}_eval_b{}", g.task, g.batch);
        let step = EvalStep::load(&reg, &name).unwrap();
        let params = reg.init_params(&g.task).unwrap();
        let x_arr = load_npy(&dir, &g.files["x"]);
        let y = load_npy(&dir, &g.files["y"]);
        let mask = load_npy(&dir, &g.files["mask"]);
        let x = match &x_arr.data {
            opacus_rs::util::npy::NpyData::F32(v) => {
                HostTensor::f32(x_arr.shape.clone(), v.clone())
            }
            opacus_rs::util::npy::NpyData::I32(v) => {
                HostTensor::i32(x_arr.shape.clone(), v.clone())
            }
            _ => panic!("unexpected x dtype"),
        };
        let (loss_sum, correct) = step
            .run(&params, x, y.as_i32().unwrap(), mask.as_f32().unwrap())
            .unwrap();
        let wl = load_npy(&dir, &g.files["out_loss_sum"]).as_f32().unwrap()[0] as f64;
        let wc = load_npy(&dir, &g.files["out_correct"]).as_f32().unwrap()[0] as f64;
        assert!(
            (loss_sum - wl).abs() < 1e-3 * wl.abs().max(1.0),
            "{name}: loss_sum {loss_sum} vs {wl}"
        );
        assert_eq!(correct, wc, "{name}: correct count");
    }
}

/// Virtual steps: accum(half A) + accum(half B) + apply == fused dp_step.
#[test]
fn virtual_steps_equal_fused_step() {
    let dir = require_artifacts!();
    let reg = Registry::open(&dir).unwrap();
    let g = reg
        .manifest
        .goldens
        .iter()
        .find(|g| g.step == "dp" && g.task == "mnist")
        .unwrap()
        .clone();

    // fused result from the golden files
    let want = load_npy(&dir, &g.files["out_params"]);
    let params = load_npy(&dir, &g.files["params"]);
    let x = load_npy(&dir, &g.files["x"]);
    let y = load_npy(&dir, &g.files["y"]);
    let noise = load_npy(&dir, &g.files["noise"]);

    let accum = AccumStep::load(&reg, "mnist_accum_b64").unwrap();
    let apply = ApplyStep::load(&reg, "mnist_apply_b64").unwrap();
    let phys = accum.batch(); // 64 > 16, so one padded chunk
    let b = g.batch;
    let per: usize = x.shape[1..].iter().product();

    // assemble one padded physical batch holding the 16 golden samples
    let xf = x.as_f32().unwrap();
    let mut xbuf = Vec::with_capacity(phys * per);
    xbuf.extend_from_slice(xf);
    for _ in b..phys {
        xbuf.extend_from_slice(&xf[..per]);
    }
    let mut shape = vec![phys];
    shape.extend_from_slice(&x.shape[1..]);
    let mut yv = y.as_i32().unwrap().to_vec();
    yv.resize(phys, yv[0]);
    let mut mask = vec![1.0f32; b];
    mask.resize(phys, 0.0);

    let out = accum
        .run(
            params.as_f32().unwrap(),
            HostTensor::f32(shape, xbuf),
            &yv,
            &mask,
            g.scalars["clip"] as f32,
        )
        .unwrap();
    let hp = HyperParams {
        lr: g.scalars["lr"] as f32,
        clip: g.scalars["clip"] as f32,
        sigma: g.scalars["sigma"] as f32,
        denom: g.scalars["denom"] as f32,
    };
    let new_params = apply
        .run(
            params.as_f32().unwrap(),
            &out.gsum,
            noise.as_f32().unwrap(),
            hp,
        )
        .unwrap();
    assert_close(
        &new_params,
        want.as_f32().unwrap(),
        5e-4,
        1e-5,
        "virtual == fused",
    );
}

/// The two-line API end to end: training reduces loss; ε grows and is
/// consistent with a fresh accountant over the same history.
#[test]
fn make_private_trains_and_accounts() {
    let dir = require_artifacts!();
    let sys = Opacus::load_with_data(&dir, "mnist", 256, 64, 7).unwrap();
    let engine = PrivacyEngine::try_new(EngineConfig {
        seed: 3,
        ..Default::default()
    }).unwrap();
    let pp = PrivacyParams::new(0.8, 1.2)
        .with_lr(0.25)
        .with_batches(64, 64);
    let mut trainer = engine.make_private(sys, pp).unwrap();
    assert_eq!(trainer.steps_per_epoch(), 4); // Poisson: ceil(1/q), q=64/256

    let losses = trainer.train_epochs(4).unwrap();
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
    let eps = trainer.epsilon(1e-5).unwrap();
    assert!(eps > 0.0 && eps.is_finite());
    assert_eq!(trainer.global_step(), 16);
    // metrics recorded per logical step
    assert_eq!(trainer.metrics.len(), 16);
    let (eval_loss, acc) = trainer.evaluate().unwrap();
    assert!(eval_loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
}

/// Uniform fused mode: logical == physical, no Poisson.
#[test]
fn fused_uniform_mode_trains() {
    let dir = require_artifacts!();
    let sys = Opacus::load_with_data(&dir, "mnist", 128, 32, 1).unwrap();
    let engine = PrivacyEngine::try_new(EngineConfig {
        seed: 5,
        ..Default::default()
    }).unwrap();
    let pp = PrivacyParams::new(0.5, 1.0)
        .with_lr(0.3)
        .with_batches(16, 16)
        .uniform_sampling();
    let mut trainer = engine.make_private(sys, pp).unwrap();
    let losses = trainer.train_epochs(3).unwrap();
    assert_eq!(trainer.global_step(), 24); // 128/16 = 8 steps × 3 epochs
    assert!(losses.iter().all(|l| l.is_finite()));
}

/// Calibrated training: achieved ε must not exceed the target.
#[test]
fn make_private_with_epsilon_respects_budget() {
    let dir = require_artifacts!();
    let sys = Opacus::load_with_data(&dir, "mnist", 256, 32, 2).unwrap();
    let engine = PrivacyEngine::try_new(EngineConfig {
        seed: 9,
        ..Default::default()
    }).unwrap();
    let pp = PrivacyParams::new(0.0, 1.0).with_batches(64, 64);
    let epochs = 3;
    let mut trainer = engine
        .make_private_with_epsilon(sys, pp, 5.0, 1e-5, epochs)
        .unwrap();
    trainer.train_epochs(epochs).unwrap();
    let eps = trainer.epsilon(1e-5).unwrap();
    assert!(eps <= 5.0 * 1.01, "ε = {eps} exceeds target 5.0");
    assert!(eps > 1.0, "ε = {eps} suspiciously small — calibration too loose");
}

/// Secure mode end to end (ChaCha20 noise + sampling).
#[test]
fn secure_mode_trains() {
    let dir = require_artifacts!();
    let sys = Opacus::load_with_data(&dir, "mnist", 128, 32, 3).unwrap();
    let engine = PrivacyEngine::try_new(EngineConfig {
        secure_mode: true,
        deterministic: true,
        seed: 11,
        ..Default::default()
    }).unwrap();
    let pp = PrivacyParams::new(1.0, 1.0).with_batches(64, 64);
    let mut trainer = engine.make_private(sys, pp).unwrap();
    let loss = trainer.train_epoch().unwrap();
    assert!(loss.is_finite());
}

/// The embedding task (i32 inputs) round-trips through the runtime.
#[test]
fn embed_task_trains() {
    let dir = require_artifacts!();
    let sys = Opacus::load_with_data(&dir, "embed", 256, 64, 4).unwrap();
    let engine = PrivacyEngine::try_new(EngineConfig {
        seed: 13,
        ..Default::default()
    }).unwrap();
    let pp = PrivacyParams::new(0.7, 1.0).with_lr(0.5).with_batches(64, 64);
    let mut trainer = engine.make_private(sys, pp).unwrap();
    let losses = trainer.train_epochs(3).unwrap();
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "embed loss did not decrease: {losses:?}"
    );
}

/// Acceptance: the typed builder produces a working trainer with the
/// three-object bundle (trainer + optimizer handle + loader handle).
#[test]
fn builder_constructs_working_trainer() {
    let dir = require_artifacts!();
    let sys = Opacus::load_with_data(&dir, "mnist", 256, 64, 7).unwrap();
    let mut private = PrivacyEngine::private()
        .noise_multiplier(1.1)
        .max_grad_norm(1.0)
        .lr(0.25)
        .seed(3)
        .build(sys)
        .unwrap();
    assert_eq!(private.optimizer.noise_multiplier, 1.1);
    assert_eq!(private.optimizer.effective_clip, 1.0);
    assert_eq!(private.loader.sampling, SamplingMode::Poisson);
    assert_eq!(private.loader.steps_per_epoch, 4); // ceil(1/q), q = 64/256
    let losses = private.train_epochs(2).unwrap();
    assert_eq!(losses.len(), 2);
    assert!(private.epsilon(1e-5).unwrap() > 0.0);
    assert_eq!(private.global_step(), 8);
}

/// Acceptance: `.target_epsilon(3.0, 1e-5, 3)` calibrates σ and training
/// the planned epochs stays within the budget.
#[test]
fn builder_target_epsilon_calibrates() {
    let dir = require_artifacts!();
    let sys = Opacus::load_with_data(&dir, "mnist", 256, 32, 2).unwrap();
    let mut private = PrivacyEngine::private()
        .target_epsilon(3.0, 1e-5, 3)
        .seed(9)
        .build(sys)
        .unwrap();
    assert!(private.optimizer.noise_multiplier > 0.0);
    private.train_epochs(3).unwrap();
    let eps = private.epsilon(1e-5).unwrap();
    assert!(eps <= 3.0 * 1.05, "ε = {eps} exceeds 1.05 × target 3.0");
    assert!(eps > 0.5, "ε = {eps} suspiciously small — calibration too loose");
}

/// Builder + GDP accountant end to end.
#[test]
fn builder_gdp_accountant_trains() {
    let dir = require_artifacts!();
    let sys = Opacus::load_with_data(&dir, "mnist", 128, 32, 4).unwrap();
    let mut private = PrivacyEngine::private()
        .accountant(AccountantKind::Gdp)
        .noise_multiplier(1.0)
        .max_grad_norm(1.0)
        .seed(5)
        .build(sys)
        .unwrap();
    assert_eq!(private.engine().accountant_mechanism(), "gdp");
    private.train_epoch().unwrap();
    assert!(private.epsilon(1e-5).unwrap() > 0.0);
}

/// Per-layer clipping: trains, and the effective clip handed to the
/// steps is C/√L while the configured max_grad_norm stays C.
#[test]
fn builder_per_layer_clipping_trains() {
    let dir = require_artifacts!();
    let sys = Opacus::load_with_data(&dir, "mnist", 128, 32, 6).unwrap();
    let num_layers = sys.model.layer_kinds.len().max(1);
    let mut private = PrivacyEngine::private()
        .clipping(ClippingStrategy::PerLayer)
        .noise_multiplier(1.0)
        .max_grad_norm(1.0)
        .seed(6)
        .build(sys)
        .unwrap();
    assert_eq!(private.optimizer.max_grad_norm, 1.0);
    let want = 1.0 / (num_layers as f64).sqrt();
    assert!((private.optimizer.effective_clip - want).abs() < 1e-12);
    let loss = private.train_epoch().unwrap();
    assert!(loss.is_finite());
}

/// The BatchMemoryManager virtualizes logical batch 512 over physical
/// batch 64 (8 accumulation micro-steps per logical step) and spends the
/// SAME ε as the monolithic make_private path with identical parameters.
#[test]
fn batch_memory_manager_matches_monolithic_epsilon() {
    let dir = require_artifacts!();

    // builder path: logical 512 over physical 64
    let sys = Opacus::load_with_data(&dir, "mnist", 1024, 64, 7).unwrap();
    let mut private = PrivacyEngine::private()
        .noise_multiplier(1.0)
        .max_grad_norm(1.0)
        .lr(0.1)
        .logical_batch(512)
        .physical_batch(64)
        .seed(3)
        .build(sys)
        .unwrap();
    assert_eq!(private.loader.steps_per_epoch, 2); // ceil(1/q), q = 512/1024
    private.train_epoch().unwrap();
    let bmm = private.memory_manager().expect("virtual mode has a manager");
    assert_eq!(bmm.logical_steps(), 2);
    assert!(
        bmm.amplification() > 4.0,
        "E[micro/logical] ≈ 8, got {}",
        bmm.amplification()
    );
    assert!(bmm.peak_logical_batch() > 64, "logical batches exceed physical");
    let eps_virtual = private.epsilon(1e-5).unwrap();

    // monolithic path: same (σ, q) and the same number of logical steps
    let sys = Opacus::load_with_data(&dir, "mnist", 1024, 64, 7).unwrap();
    let engine = PrivacyEngine::try_new(EngineConfig {
        seed: 3,
        ..Default::default()
    }).unwrap();
    let pp = PrivacyParams::new(1.0, 1.0).with_lr(0.1).with_batches(512, 64);
    let mut trainer = engine.make_private(sys, pp).unwrap();
    trainer.train_epoch().unwrap();
    let eps_monolithic = trainer.epsilon(1e-5).unwrap();

    assert!(
        (eps_virtual - eps_monolithic).abs() < 1e-12,
        "virtualized ε = {eps_virtual} != monolithic ε = {eps_monolithic}"
    );
}

/// The facade's `Opacus::make_private()` builder alias works too.
#[test]
fn facade_builder_entry_point() {
    let dir = require_artifacts!();
    let sys = Opacus::load_with_data(&dir, "mnist", 128, 32, 8).unwrap();
    let mut private = Opacus::make_private()
        .noise_multiplier(1.0)
        .max_grad_norm(1.0)
        .build(sys)
        .unwrap();
    assert!(private.train_epoch().unwrap().is_finite());
}

/// Compile log records the first-epoch "JIT analogue" cost (Fig. 4).
#[test]
fn compile_log_populated() {
    let dir = require_artifacts!();
    let reg = Registry::open(&dir).unwrap();
    assert!(reg.compile_log().is_empty());
    let _ = TrainStep::load(&reg, "mnist_nodp_b16").unwrap();
    let log = reg.compile_log();
    assert_eq!(log.len(), 1);
    assert!(log[0].1 > 0.0);
    // cached second load: no new compile entry
    let _ = TrainStep::load(&reg, "mnist_nodp_b16").unwrap();
    assert_eq!(reg.compile_log().len(), 1);
}
