//! Observability integration tests — the PR-8 acceptance criteria:
//!
//! * determinism: with span/counter collection ON, training spends the
//!   byte-identical ε and lands on bitwise-identical parameters as with
//!   collection OFF — at 1 and 4 workers and through the prefetch
//!   pipeline (instrumentation only reads clocks);
//! * the exported chrome://tracing JSON parses and carries both span
//!   (`ph: "X"`) and lane-naming metadata (`ph: "M"`) events;
//! * `opacus serve` rewrites a per-job `status.json` whose ε field
//!   matches the engine's reported ε bit for bit.

use std::path::PathBuf;

use opacus_rs::coordinator::Opacus;
use opacus_rs::obs;
use opacus_rs::privacy::{Backend, NoiseSource, PrivacyEngine, SamplingMode};
use opacus_rs::serve::{JobSpec, JobStatus, ServeConfig, Service};
use opacus_rs::util::json::Json;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("opacus_obs_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Train 2 epochs of mnist under the deterministic noise source and
/// return (ε, parameter bits). The observability flag is whatever the
/// caller set — that is the point.
fn run(workers: usize, pipeline: Option<usize>) -> (f64, Vec<u32>) {
    let sys = Opacus::load_with_backend(
        "artifacts_that_do_not_exist",
        "mnist",
        Backend::Native,
        192,
        32,
        11,
    )
    .unwrap();
    let mut builder = PrivacyEngine::private()
        .backend(Backend::Native)
        .noise(NoiseSource::Deterministic)
        .workers(workers)
        .sampling(SamplingMode::Uniform)
        .noise_multiplier(0.8)
        .max_grad_norm(1.0)
        .lr(0.2)
        .logical_batch(32)
        .physical_batch(32)
        .seed(17);
    if let Some(d) = pipeline {
        builder = builder.pipeline(d);
    }
    let mut private = builder.build(sys).unwrap();
    private.train_epochs(2).unwrap();
    let eps = private.epsilon(1e-5).unwrap();
    let (trainer, _, _) = private.into_parts();
    (eps, trainer.params.iter().map(|p| p.to_bits()).collect())
}

/// The determinism contract, end to end: collection off → collection on
/// over the same recipes (1 worker, 4 workers, pipelined) must agree on
/// every ε bit and every parameter bit. The enabled flag is process
/// global, so this single test owns both transitions — no other test in
/// this binary touches the flag. While collection is on, the recorded
/// spans are exported and the trace-event JSON schema is checked.
#[test]
fn tracing_changes_no_epsilon_or_parameter_bits() {
    let cases = [(1, None), (4, None), (1, Some(2)), (4, Some(2))];
    let off: Vec<(f64, Vec<u32>)> = cases.iter().map(|&(w, p)| run(w, p)).collect();

    obs::set_enabled(true);
    let on: Vec<(f64, Vec<u32>)> = cases.iter().map(|&(w, p)| run(w, p)).collect();
    assert!(
        obs::trace::event_count() > 0,
        "collection was on: spans must have been recorded"
    );
    let dir = tmpdir("trace");
    let path = dir.join("trace.json");
    obs::trace::export(&path).unwrap();
    obs::set_enabled(false);
    obs::reset();

    for (i, (o, n)) in off.iter().zip(on.iter()).enumerate() {
        let (workers, pipeline) = cases[i];
        assert_eq!(
            o.0.to_bits(),
            n.0.to_bits(),
            "workers={workers} pipeline={pipeline:?}: ε must be byte-identical with tracing on"
        );
        assert_eq!(
            o.1, n.1,
            "workers={workers} pipeline={pipeline:?}: params must be bitwise identical"
        );
    }

    // the exported trace is valid chrome://tracing JSON: span events on
    // named lanes (worker threads included — the 4-worker case ran)
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let events = doc.get("traceEvents").as_arr().expect("traceEvents array");
    let spans = events
        .iter()
        .filter(|e| e.get("ph").as_str() == Some("X"))
        .count();
    let lanes: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").as_str() == Some("M"))
        .filter_map(|e| e.get("args").get("name").as_str())
        .collect();
    assert!(spans > 0, "trace must contain span events");
    assert!(!lanes.is_empty(), "trace must name its lanes");
    assert!(
        lanes.iter().any(|n| n.starts_with("opacus-worker-")),
        "worker threads get their own lanes, got {lanes:?}"
    );
    assert_eq!(
        doc.get("otherData").get("format").as_str(),
        Some(obs::trace::TRACE_FORMAT)
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// serve writes `<out>/<name>.status.json` at every quantum boundary;
/// after a run to graceful exhaustion the file must parse, report the
/// terminal state, and carry the engine's ε bit for bit.
#[test]
fn serve_status_file_matches_engine_epsilon_exactly() {
    let out = tmpdir("status");
    let mut cfg = ServeConfig::new(&out);
    cfg.quantum = 4;
    let mut svc = Service::new(cfg);
    let spec = JobSpec::from_json(
        &Json::parse(
            r#"{"name":"budgeted","task":"mnist","backend":"native","epsilon":5.0,
                "delta":1e-5,"sigma":1.0,"batch":32,"train":192,"lr":0.2,"seed":17}"#,
        )
        .unwrap(),
    )
    .unwrap();
    svc.submit(spec).unwrap();
    let reports = svc.run().unwrap();
    assert_eq!(reports[0].status, JobStatus::Exhausted);

    let text = std::fs::read_to_string(out.join("budgeted.status.json")).unwrap();
    let status = obs::StatusReport::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(status.state, "exhausted");
    assert_eq!(status.step, reports[0].steps);
    assert_eq!(status.task, "mnist");

    let engine_eps = svc.trainer("budgeted").unwrap().epsilon(1e-5).unwrap();
    assert_eq!(
        status.epsilon.to_bits(),
        engine_eps.to_bits(),
        "status.json ε must match the engine ε bit for bit ({} vs {engine_eps})",
        status.epsilon
    );
    assert_eq!(status.epsilon_budget, 5.0);
    assert!(
        status.budget_burn > 0.0 && status.budget_burn <= 1.0,
        "burn-down must be a fraction of budget, got {}",
        status.budget_burn
    );
    // atomic writer: no .tmp sibling survives
    assert!(!out.join("budgeted.status.json.tmp").exists());
    let _ = std::fs::remove_dir_all(&out);
}
