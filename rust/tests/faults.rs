//! Fault-tolerance integration tests — the PR-10 acceptance criteria,
//! on the always-on native backend:
//!
//! * determinism under faults: a training run with scripted worker
//!   panics, slow shards and checkpoint write failures produces ε and
//!   parameters byte-identical to a fault-free run, across worker
//!   counts and pipeline depths;
//! * checkpoint rollback: when the *latest* checkpoint generation is
//!   corrupted, `serve --resume` rolls back to the newest generation
//!   that verifies and finishes with byte-identical ε;
//! * non-finite containment: a poisoned loss/gradient is a typed error
//!   naming the step — no parameter update, no budget spend;
//! * quarantine: a job that fails unrecoverably is marked `failed` with
//!   a terminal status file while sibling jobs run to completion.
//!
//! The fault plan's step/save clocks are thread-confined and the
//! enable gate is process-global, so every test here serializes on one
//! mutex and clears the plan on entry.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use opacus_rs::coordinator::Opacus;
use opacus_rs::faults::{self, FaultPlan};
use opacus_rs::obs::StatusReport;
use opacus_rs::privacy::{Backend, NoiseSource, PrivacyEngine, SamplingMode};
use opacus_rs::serve::{JobSpec, JobStatus, ServeConfig, Service, TrainerCheckpoint};
use opacus_rs::trainer::PrivateTrainer;
use opacus_rs::util::json::Json;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("opacus_faults_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn bits(params: &[f32]) -> Vec<u32> {
    params.iter().map(|p| p.to_bits()).collect()
}

/// A small deterministic fused-path trainer on the worker pool, with an
/// optional prefetch pipeline and an optional fault plan.
fn build(workers: usize, pipeline: Option<usize>, plan: Option<&str>) -> PrivateTrainer {
    let sys = Opacus::load_with_backend(
        "artifacts_that_do_not_exist",
        "mnist",
        Backend::Native,
        192,
        32,
        11,
    )
    .unwrap();
    let mut builder = PrivacyEngine::private()
        .backend(Backend::Native)
        .noise(NoiseSource::Deterministic)
        .sampling(SamplingMode::Uniform)
        .noise_multiplier(0.8)
        .max_grad_norm(1.0)
        .lr(0.2)
        .logical_batch(32)
        .physical_batch(32)
        .seed(17)
        .workers(workers);
    if let Some(d) = pipeline {
        builder = builder.pipeline(d);
    }
    if let Some(text) = plan {
        builder = builder.faults(FaultPlan::parse(text).unwrap());
    }
    builder.build(sys).unwrap().into_trainer()
}

/// Train `quanta` quanta of `quantum` steps, checkpointing after each —
/// the serve cadence, so the fault plan's save clock advances too.
fn run_quanta(
    t: &mut PrivateTrainer,
    quanta: usize,
    quantum: usize,
    ckpt: &Path,
) -> (f64, Vec<u32>) {
    for _ in 0..quanta {
        t.train_steps(quantum).unwrap();
        TrainerCheckpoint::capture(t).save(ckpt).unwrap();
    }
    (t.epsilon(1e-5).unwrap(), bits(&t.params))
}

/// The headline invariant: scripted worker panics, slow shards and a
/// checkpoint write failure change *nothing* about the result — ε bits
/// and parameter bits match a fault-free run, for 1 and 4 workers, with
/// and without the prefetch pipeline.
#[test]
fn faulted_training_is_byte_identical_to_clean() {
    let _guard = lock();
    faults::clear();
    let dir = tmpdir("identity");
    let configs: [(usize, Option<usize>); 4] = [(1, None), (1, Some(2)), (4, None), (4, Some(2))];
    for (i, (workers, pipeline)) in configs.into_iter().enumerate() {
        let mut clean = build(workers, pipeline, None);
        let (eps_clean, params_clean) = run_quanta(&mut clean, 3, 2, &dir.join(format!("c{i}")));

        let plan = format!(
            r#"{{"format":"opacus-rs/faults","version":1,"faults":[
                {{"kind":"worker_panic","step":2,"rank":{}}},
                {{"kind":"slow_shard","step":1,"rank":0,"millis":2}},
                {{"kind":"checkpoint_write_fail","save":1}}
            ]}}"#,
            workers - 1
        );
        let respawns_before = faults::respawns();
        let retries_before = faults::ckpt_retries();
        let mut faulted = build(workers, pipeline, Some(&plan));
        let (eps_faulted, params_faulted) =
            run_quanta(&mut faulted, 3, 2, &dir.join(format!("f{i}")));
        assert_eq!(
            faults::pending(),
            0,
            "workers={workers} pipeline={pipeline:?}: every scripted fault must fire"
        );
        faults::clear();
        assert!(faults::respawns() > respawns_before, "the panic was recovered");
        assert!(faults::ckpt_retries() > retries_before, "the write fail was retried");
        assert_eq!(
            eps_clean.to_bits(),
            eps_faulted.to_bits(),
            "workers={workers} pipeline={pipeline:?}: ε must be byte-identical \
             ({eps_clean} vs {eps_faulted})"
        );
        assert_eq!(
            params_clean, params_faulted,
            "workers={workers} pipeline={pipeline:?}: params must be bit-identical"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

fn tight_spec(name: &str, epsilon: f64) -> JobSpec {
    let json = format!(
        r#"{{"name":"{name}","task":"mnist","backend":"native","epsilon":{epsilon},
            "delta":1e-5,"sigma":1.0,"batch":32,"train":192,"lr":0.2,"seed":17}}"#
    );
    JobSpec::from_json(&Json::parse(&json).unwrap()).unwrap()
}

/// Corrupt the params payload of the checkpoint at `dir`.
fn corrupt(dir: &Path) {
    let p = dir.join("params.npy");
    let mut bytes = std::fs::read(&p).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x40;
    std::fs::write(&p, bytes).unwrap();
}

/// Kill a served job, corrupt its latest checkpoint generation(s), and
/// resume: the service rolls back to the newest generation whose CRCs
/// verify, replays forward, and lands on ε byte-identical to a service
/// that was never killed.
#[test]
fn corrupt_latest_generation_rolls_back_with_exact_epsilon() {
    let _guard = lock();
    faults::clear();

    // reference service: never killed
    let ref_out = tmpdir("roll_ref");
    let mut cfg = ServeConfig::new(&ref_out);
    cfg.quantum = 2;
    let mut svc = Service::new(cfg);
    svc.submit(tight_spec("job", 6.0)).unwrap();
    let reference = svc.run().unwrap();
    assert_eq!(reference[0].status, JobStatus::Exhausted);

    // killed service: two quanta plus the interrupt save → generations
    // 1 (step 2), 2 (step 4) and the live dir (step 4)
    let out = tmpdir("roll_killed");
    let mut cfg = ServeConfig::new(&out);
    cfg.quantum = 2;
    cfg.kill_after = Some(4);
    let mut svc = Service::new(cfg);
    svc.submit(tight_spec("job", 6.0)).unwrap();
    let killed = svc.run().unwrap();
    assert_eq!(killed[0].status, JobStatus::Interrupted);

    // corrupt the live checkpoint AND the newest ring sibling — the
    // resume must walk back to the oldest surviving generation (step 2)
    corrupt(&out.join("job"));
    let newest_sibling = {
        let mut gens: Vec<PathBuf> = std::fs::read_dir(&out)
            .unwrap()
            .flatten()
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("job.gen"))
            })
            .collect();
        gens.sort();
        assert!(!gens.is_empty(), "the ring must hold at least one sibling");
        gens.pop().unwrap()
    };
    corrupt(&newest_sibling);

    let rollbacks_before = faults::rollbacks();
    let mut cfg = ServeConfig::new(&out);
    cfg.quantum = 2;
    cfg.resume = true;
    let mut svc = Service::new(cfg);
    svc.submit(tight_spec("job", 6.0)).unwrap();
    let resumed = svc.run().unwrap();
    assert_eq!(resumed[0].status, JobStatus::Exhausted);
    assert!(resumed[0].resumed);
    assert!(faults::rollbacks() > rollbacks_before, "a rollback must be recorded");

    assert_eq!(
        reference[0].epsilon.to_bits(),
        resumed[0].epsilon.to_bits(),
        "rollback + replay must reproduce ε byte-identically ({} vs {})",
        reference[0].epsilon,
        resumed[0].epsilon
    );
    assert_eq!(reference[0].steps, resumed[0].steps);

    // the status file carries the rollback odometer
    let status = StatusReport::from_json(
        &Json::parse(&std::fs::read_to_string(out.join("job.status.json")).unwrap()).unwrap(),
    )
    .unwrap();
    assert!(status.checkpoint_rollbacks >= 1);
    let _ = std::fs::remove_dir_all(&out);
    let _ = std::fs::remove_dir_all(&ref_out);
}

/// A poisoned loss is a typed error naming the step — the optimizer
/// never applies the update and the accountant never records the step.
#[test]
fn non_finite_injection_is_typed_and_spends_nothing() {
    let _guard = lock();
    faults::clear();
    let mut t = build(2, None, None);
    let params_before = bits(&t.params);
    faults::install(
        FaultPlan::parse(
            r#"{"format":"opacus-rs/faults","version":1,"faults":[
                {"kind":"non_finite_loss","step":1}
            ]}"#,
        )
        .unwrap(),
    );
    let err = t.train_steps(2).unwrap_err();
    faults::clear();
    let msg = format!("{err:#}");
    assert!(msg.contains("at step 1"), "{msg}");
    assert!(msg.contains("non-finite loss"), "{msg}");
    assert_eq!(t.global_step(), 0, "the poisoned step must not be recorded");
    assert_eq!(bits(&t.params), params_before, "no parameter update from poison");
}

/// One job poisoned, one healthy: the scheduler quarantines the
/// poisoned job (`failed` status file with the error) and the healthy
/// sibling still runs to graceful exhaustion.
#[test]
fn serve_quarantines_a_poisoned_job_and_siblings_finish() {
    let _guard = lock();
    faults::clear();
    let out = tmpdir("quarantine");
    faults::install(
        FaultPlan::parse(
            r#"{"format":"opacus-rs/faults","version":1,"faults":[
                {"kind":"non_finite_grad","step":1}
            ]}"#,
        )
        .unwrap(),
    );
    let mut cfg = ServeConfig::new(&out);
    cfg.quantum = 2;
    let mut svc = Service::new(cfg);
    // job 0 runs first, so the global step clock poisons its first step
    svc.submit(tight_spec("bad", 6.0)).unwrap();
    svc.submit(tight_spec("good", 6.0)).unwrap();
    let reports = svc.run().unwrap();
    faults::clear();

    assert_eq!(reports[0].status, JobStatus::Failed);
    assert_eq!(reports[1].status, JobStatus::Exhausted, "siblings keep running");

    let status = StatusReport::from_json(
        &Json::parse(&std::fs::read_to_string(out.join("bad.status.json")).unwrap()).unwrap(),
    )
    .unwrap();
    assert_eq!(status.state, "failed");
    let error = status.error.expect("failed status carries the error");
    assert!(error.contains("non-finite"), "{error}");
    let _ = std::fs::remove_dir_all(&out);
}

/// With no plan installed the harness is inert, and malformed plans are
/// typed errors.
#[test]
fn faults_are_off_by_default_and_plans_are_validated() {
    let _guard = lock();
    faults::clear();
    assert!(!faults::enabled());
    assert_eq!(faults::pending(), 0);
    let err = FaultPlan::parse(
        r#"{"format":"opacus-rs/faults","version":1,"faults":[{"kind":"meteor","step":1}]}"#,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("meteor"), "{err}");
    assert!(
        FaultPlan::parse(r#"{"format":"something/else","version":1,"faults":[]}"#).is_err()
    );
}
