//! Streaming-service integration tests — the PR-6 acceptance criteria,
//! on the always-on native backend:
//!
//! * pipeline determinism: `.pipeline(depth)` produces byte-identical
//!   ε and parameters to strict sequential execution under the
//!   deterministic noise source;
//! * accountant durability: serializing accountant state through the
//!   checkpoint format and replaying it reproduces ε bit-identically,
//!   across both accountants and a (q, σ, steps) grid;
//! * kill/resume parity: a run interrupted at an arbitrary step and
//!   resumed from its checkpoint lands on byte-identical ε and
//!   parameters within 1e-6 (bitwise, in fact) of the uninterrupted run;
//! * the serve scheduler: concurrent jobs at distinct (ε, δ) budgets,
//!   graceful budget exhaustion, and kill + `--resume` continuity.

use std::path::PathBuf;

use opacus_rs::accounting::{Accountant, GdpAccountant, RdpAccountant};
use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{Backend, NoiseSource, PrivacyEngine, SamplingMode};
use opacus_rs::serve::{
    checkpoint_exists, JobSpec, JobStatus, ServeConfig, Service, TrainerCheckpoint,
};
use opacus_rs::trainer::{MetricsLog, PrivateTrainer};
use opacus_rs::util::json::Json;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("opacus_serve_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A small deterministic fused-path trainer (uniform sampling,
/// logical == physical) with an optional prefetch pipeline.
fn build_trainer(task: &str, pipeline: Option<usize>) -> PrivateTrainer {
    let sys = Opacus::load_with_backend(
        "artifacts_that_do_not_exist",
        task,
        Backend::Native,
        192,
        32,
        11,
    )
    .unwrap();
    let mut builder = PrivacyEngine::private()
        .backend(Backend::Native)
        .noise(NoiseSource::Deterministic)
        .sampling(SamplingMode::Uniform)
        .noise_multiplier(0.8)
        .max_grad_norm(1.0)
        .lr(0.2)
        .logical_batch(32)
        .physical_batch(32)
        .seed(17);
    if let Some(d) = pipeline {
        builder = builder.pipeline(d);
    }
    builder.build(sys).unwrap().into_trainer()
}

fn bits(params: &[f32]) -> Vec<u32> {
    params.iter().map(|p| p.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// tentpole layer 1: the step pipeline
// ---------------------------------------------------------------------------

/// The determinism contract: pipelined execution is byte-identical to
/// sequential — same ε bits, same parameter bits — at several depths,
/// on both a feed-forward and the recurrent task.
#[test]
fn pipelined_training_is_byte_identical_to_sequential() {
    for task in ["mnist", "lstm"] {
        let mut seq = build_trainer(task, None);
        seq.train_epochs(2).unwrap();
        let eps_seq = seq.epsilon(1e-5).unwrap();
        for depth in [1, 3] {
            let mut pip = build_trainer(task, Some(depth));
            assert_eq!(pip.pipeline_depth(), Some(depth));
            pip.train_epochs(2).unwrap();
            let eps_pip = pip.epsilon(1e-5).unwrap();
            assert_eq!(
                eps_seq.to_bits(),
                eps_pip.to_bits(),
                "{task} depth {depth}: ε must be byte-identical"
            );
            assert_eq!(
                bits(&seq.params),
                bits(&pip.params),
                "{task} depth {depth}: params must be byte-identical"
            );
        }
    }
}

/// The pipeline reports stage occupancy into the metrics log, and the
/// `pipelined` flag tracks which path ran.
#[test]
fn pipeline_stats_are_recorded() {
    let mut seq = build_trainer("mnist", None);
    seq.train_epochs(1).unwrap();
    let s = seq.metrics.pipeline.expect("sequential run records stats");
    assert!(!s.pipelined);
    assert_eq!(s.steps, seq.global_step());
    assert!(s.wall_secs > 0.0);

    let mut pip = build_trainer("mnist", Some(2));
    pip.train_epochs(1).unwrap();
    let p = pip.metrics.pipeline.expect("pipelined run records stats");
    assert!(p.pipelined);
    assert_eq!(p.steps, pip.global_step());
    assert!(p.steps_per_sec() > 0.0);
}

// ---------------------------------------------------------------------------
// tentpole layer 2: durable checkpoints
// ---------------------------------------------------------------------------

/// Accountant-state durability over a (q, σ, steps) grid: history
/// serialized through the on-disk checkpoint format and replayed into a
/// fresh accountant reproduces ε bit-identically, for RDP and GDP.
#[test]
fn accountant_round_trips_epsilon_bit_identical() {
    let dir = tmpdir("acct_grid");
    let grid: Vec<(f64, f64, u64)> = vec![
        (1.0 / 6.0, 0.8, 7),
        (0.01, 1.1, 500),
        (0.004, 1.0, 2344),
        (0.05, 2.0, 91),
    ];
    for mech in ["rdp", "gdp"] {
        let fresh = |hist: &[opacus_rs::accounting::HistoryEntry]| -> Box<dyn Accountant> {
            let mut a: Box<dyn Accountant> = match mech {
                "rdp" => Box::new(RdpAccountant::new()),
                _ => Box::new(GdpAccountant::new()),
            };
            for h in hist {
                a.record(h.noise_multiplier, h.sample_rate, h.steps);
            }
            a
        };
        for &(q, sigma, steps) in &grid {
            // a composite ledger: two σ phases, as a noise schedule writes
            let history = vec![
                opacus_rs::accounting::HistoryEntry {
                    noise_multiplier: sigma,
                    sample_rate: q,
                    steps,
                },
                opacus_rs::accounting::HistoryEntry {
                    noise_multiplier: sigma * 1.5,
                    sample_rate: q,
                    steps: steps / 2 + 1,
                },
            ];
            let want = fresh(&history).get_epsilon(1e-5);

            // through the full on-disk checkpoint format
            let ck = TrainerCheckpoint {
                task: "grid".into(),
                epoch: 0,
                global_step: steps,
                params: vec![0.0; 4],
                history: history.clone(),
                mechanism: mech.into(),
                rng_words: None,
                pending: Vec::new(),
                memory_stats: None,
                noise_multiplier: sigma,
                logical_batch: 32,
                metrics: MetricsLog::new(),
            };
            let path = dir.join(format!("{mech}_{steps}"));
            ck.save(&path).unwrap();
            let back = TrainerCheckpoint::load(&path).unwrap();
            assert_eq!(back.history, history, "{mech} q={q} σ={sigma}");
            let got = fresh(&back.history).get_epsilon(1e-5);
            assert_eq!(
                want.to_bits(),
                got.to_bits(),
                "{mech} q={q} σ={sigma} steps={steps}: ε {want} != {got}"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kill/resume parity: a run checkpointed mid-epoch and resumed into a
/// fresh trainer matches the uninterrupted run — ε byte-identical,
/// params bitwise identical (comfortably within the 1e-6 criterion).
#[test]
fn kill_and_resume_matches_uninterrupted_run() {
    let dir = tmpdir("kill_resume");
    // reference: 2 epochs straight through
    let mut reference = build_trainer("mnist", None);
    reference.train_epochs(2).unwrap();
    let eps_ref = reference.epsilon(1e-5).unwrap();
    let total = reference.global_step() as usize;

    // killed run: stop mid-epoch at an awkward step count, checkpoint
    let mut killed = build_trainer("mnist", None);
    killed.train_steps(5).unwrap();
    let ckpt = dir.join("job");
    TrainerCheckpoint::capture(&killed).save(&ckpt).unwrap();
    drop(killed); // the process is gone

    // resume into a freshly built trainer and finish the budgeted steps
    let mut resumed = build_trainer("mnist", None);
    TrainerCheckpoint::load(&ckpt)
        .unwrap()
        .apply(&mut resumed)
        .unwrap();
    assert_eq!(resumed.global_step(), 5);
    resumed.train_steps(total - 5).unwrap();

    let eps_res = resumed.epsilon(1e-5).unwrap();
    assert_eq!(
        eps_ref.to_bits(),
        eps_res.to_bits(),
        "ε after resume must be byte-identical ({eps_ref} vs {eps_res})"
    );
    assert_eq!(
        bits(&reference.params),
        bits(&resumed.params),
        "params after resume must be byte-identical"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A checkpoint refuses to restore into a trainer built from a different
/// recipe (different σ or task) — config drift is an error, not silence.
#[test]
fn checkpoint_rejects_mismatched_trainer() {
    let dir = tmpdir("mismatch");
    let mut t = build_trainer("mnist", None);
    t.train_steps(3).unwrap();
    let ckpt = dir.join("job");
    TrainerCheckpoint::capture(&t).save(&ckpt).unwrap();

    let mut other_task = build_trainer("embed", None);
    let err = TrainerCheckpoint::load(&ckpt)
        .unwrap()
        .apply(&mut other_task)
        .unwrap_err()
        .to_string();
    assert!(err.contains("task"), "{err}");

    let mut tampered = TrainerCheckpoint::load(&ckpt).unwrap();
    tampered.noise_multiplier = 9.9;
    let mut same_task = build_trainer("mnist", None);
    let err = tampered.apply(&mut same_task).unwrap_err().to_string();
    assert!(err.contains("recipe"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// tentpole layer 3: the serve scheduler
// ---------------------------------------------------------------------------

fn spec(json: &str) -> JobSpec {
    JobSpec::from_json(&Json::parse(json).unwrap()).unwrap()
}

fn tight_spec(name: &str, epsilon: f64) -> JobSpec {
    spec(&format!(
        r#"{{"name":"{name}","task":"mnist","backend":"native","epsilon":{epsilon},
            "delta":1e-5,"sigma":1.0,"batch":32,"train":192,"lr":0.2,"seed":17}}"#
    ))
}

fn epoch_spec(name: &str) -> JobSpec {
    spec(&format!(
        r#"{{"name":"{name}","task":"embed","backend":"native","max_epochs":1,
            "sigma":1.1,"batch":32,"train":96,"seed":17}}"#
    ))
}

/// Two concurrent jobs at distinct budgets: the ε-bounded job stops
/// *before* its target (graceful exhaustion, never an error) and the
/// epoch-bounded job completes exactly at its cap.
#[test]
fn serve_runs_jobs_to_graceful_termination() {
    let out = tmpdir("serve_basic");
    let mut cfg = ServeConfig::new(&out);
    cfg.quantum = 4;
    let mut svc = Service::new(cfg);
    svc.submit(tight_spec("budgeted", 5.0)).unwrap();
    svc.submit(epoch_spec("epochy")).unwrap();
    let reports = svc.run().unwrap();
    assert_eq!(reports.len(), 2);

    let budgeted = &reports[0];
    assert_eq!(budgeted.status, JobStatus::Exhausted);
    assert!(
        budgeted.epsilon <= 5.0,
        "exhausted job spent ε = {} past its budget",
        budgeted.epsilon
    );
    assert!(budgeted.steps > 0, "budget admits at least a few steps");

    let epochy = &reports[1];
    assert_eq!(epochy.status, JobStatus::Completed);
    assert_eq!(epochy.epochs, 1);

    // both jobs left durable checkpoints behind
    assert!(checkpoint_exists(&out.join("budgeted")));
    assert!(checkpoint_exists(&out.join("epochy")));
    let _ = std::fs::remove_dir_all(&out);
}

/// Kill the service mid-run (the `kill_after` hook — same code path as
/// SIGTERM), resume it, and require the resumed service to finish with
/// ε byte-identical to a never-killed service on the same specs.
#[test]
fn serve_kill_and_resume_reproduces_epsilon() {
    // reference service: never killed
    let ref_out = tmpdir("serve_ref");
    let mut cfg = ServeConfig::new(&ref_out);
    cfg.quantum = 2;
    let mut svc = Service::new(cfg);
    svc.submit(tight_spec("job", 8.0)).unwrap();
    let reference = svc.run().unwrap();
    assert_eq!(reference[0].status, JobStatus::Exhausted);

    // killed service: stops after 2 total steps (well before the budget
    // is anywhere near spent) with a final checkpoint
    let out = tmpdir("serve_killed");
    let mut cfg = ServeConfig::new(&out);
    cfg.quantum = 2;
    cfg.kill_after = Some(2);
    let mut svc = Service::new(cfg);
    svc.submit(tight_spec("job", 8.0)).unwrap();
    let killed = svc.run().unwrap();
    assert_eq!(killed[0].status, JobStatus::Interrupted);
    assert!(killed[0].steps >= 2);
    assert!(checkpoint_exists(&out.join("job")));

    // resumed service: picks the job up and exhausts the budget
    let mut cfg = ServeConfig::new(&out);
    cfg.quantum = 2;
    cfg.resume = true;
    let mut svc = Service::new(cfg);
    svc.submit(tight_spec("job", 8.0)).unwrap();
    let resumed = svc.run().unwrap();
    assert_eq!(resumed[0].status, JobStatus::Exhausted);
    assert!(resumed[0].resumed);

    assert_eq!(
        reference[0].epsilon.to_bits(),
        resumed[0].epsilon.to_bits(),
        "kill/resume must reproduce ε byte-identically ({} vs {})",
        reference[0].epsilon,
        resumed[0].epsilon
    );
    assert_eq!(reference[0].steps, resumed[0].steps);
    // the deterministic noise source also pins the parameter bits
    let p_ref = bits(&svc.trainer("job").unwrap().params);
    let ref_trainer = {
        let mut cfg = ServeConfig::new(&ref_out);
        cfg.resume = true;
        let mut s = Service::new(cfg);
        s.submit(tight_spec("job", 8.0)).unwrap();
        bits(&s.trainer("job").unwrap().params)
    };
    assert_eq!(p_ref, ref_trainer, "params after kill/resume must match");
    let _ = std::fs::remove_dir_all(&out);
    let _ = std::fs::remove_dir_all(&ref_out);
}

/// A spec with neither a budget nor an epoch cap is rejected up front,
/// and a pipelined job spec trains under the scheduler.
#[test]
fn serve_spec_validation_and_pipelined_jobs() {
    let err = JobSpec::from_json(&Json::parse(r#"{"name":"x","task":"mnist"}"#).unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("never terminate"), "{err}");

    let out = tmpdir("serve_pipelined");
    let mut cfg = ServeConfig::new(&out);
    cfg.quantum = 4;
    let mut svc = Service::new(cfg);
    svc.submit(spec(
        r#"{"name":"p","task":"mnist","backend":"native","max_epochs":1,
            "batch":32,"train":96,"pipeline":2,"seed":17}"#,
    ))
    .unwrap();
    let reports = svc.run().unwrap();
    assert_eq!(reports[0].status, JobStatus::Completed);
    assert_eq!(reports[0].epochs, 1);
    let _ = std::fs::remove_dir_all(&out);
}
