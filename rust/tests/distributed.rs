//! Distributed-execution integration tests — the PR-3 acceptance
//! criteria, on the always-on native backend:
//!
//! * worker parity: `workers = 4` vs `workers = 1` under the
//!   deterministic noise source spends the identical ε after 3 epochs
//!   and lands on parameters within 1e-6, for all four native tasks
//!   (both the fused and the virtual/BatchMemoryManager paths);
//! * noise sources: `Secure` draws differ across engine instances while
//!   `Deterministic` is stable across instances (stability across
//!   worker counts is the parity test above — rank-0 noise comes from
//!   the same engine stream whatever the pool size);
//! * DPDDP noise splitting: per-worker σ/√N mode trains and accounts.

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{
    Backend, BackendKind, EngineConfig, NoiseDivision, NoiseSource, PrivacyEngine, SamplingMode,
};

/// Train `task` for `epochs` epochs with `workers` threads under the
/// deterministic noise source; returns (ε, params, logical steps).
fn run_task(
    task: &str,
    workers: usize,
    epochs: usize,
    sampling: SamplingMode,
) -> (f64, Vec<f32>, u64) {
    let sys = Opacus::load_with_backend(
        "artifacts_that_do_not_exist",
        task,
        Backend::Native,
        192,
        32,
        11,
    )
    .unwrap();
    let mut private = PrivacyEngine::private()
        .backend(Backend::Native)
        .noise(NoiseSource::Deterministic)
        .workers(workers)
        .sampling(sampling)
        .noise_multiplier(0.8)
        .max_grad_norm(1.0)
        .lr(0.2)
        .logical_batch(32)
        .physical_batch(32)
        .seed(17)
        .build(sys)
        .unwrap();
    assert_eq!(private.backend_kind(), BackendKind::Native);
    assert_eq!(private.workers(), workers);
    private.train_epochs(epochs).unwrap();
    let eps = private.epsilon(1e-5).unwrap();
    let (trainer, _, _) = private.into_parts();
    let steps = trainer.global_step();
    (eps, trainer.params, steps)
}

fn worst_param_diff(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (*x as f64 - *y as f64).abs())
        .fold(0.0, f64::max)
}

/// The acceptance criterion: 4 workers vs 1 worker, deterministic noise,
/// 3 epochs, all five native tasks (the lstm row is now the true
/// recurrent kernel, and attn is the new attention task) — identical ε,
/// params within 1e-6. Uniform sampling keeps logical == physical, so
/// this exercises the fused distributed path.
#[test]
fn workers4_matches_workers1_fused_all_tasks() {
    for task in ["mnist", "cifar", "embed", "lstm", "attn"] {
        let (e1, p1, s1) = run_task(task, 1, 3, SamplingMode::Uniform);
        let (e4, p4, s4) = run_task(task, 4, 3, SamplingMode::Uniform);
        assert_eq!(s1, s4, "{task}: step counts must match");
        assert_eq!(e1, e4, "{task}: ε must be identical, got {e1} vs {e4}");
        let worst = worst_param_diff(&p1, &p4);
        assert!(
            worst < 1e-6,
            "{task}: params diverged by {worst:.3e} between 1 and 4 workers"
        );
    }
}

/// The same guarantee through the virtual path: Poisson sampling routes
/// every logical step through accum chunks + one noisy apply, and the
/// BatchMemoryManager decomposition must stay worker-invariant too.
#[test]
fn workers4_matches_workers1_virtual_path() {
    for task in ["mnist", "embed", "attn"] {
        let (e1, p1, _) = run_task(task, 1, 3, SamplingMode::Poisson);
        let (e4, p4, _) = run_task(task, 4, 3, SamplingMode::Poisson);
        assert_eq!(e1, e4, "{task}: ε must be identical");
        let worst = worst_param_diff(&p1, &p4);
        assert!(
            worst < 1e-6,
            "{task}: virtual-path params diverged by {worst:.3e}"
        );
    }
}

/// `Backend::Auto` + a worker request must resolve to the native engine
/// (the only backend with a pool) rather than stranding the request on
/// whatever Auto would pick for single-threaded execution.
#[test]
fn auto_backend_with_workers_resolves_native() {
    let sys =
        Opacus::load_with_data("artifacts_that_do_not_exist", "embed", 96, 32, 1).unwrap();
    let private = PrivacyEngine::private()
        .workers(2) // note: no explicit .backend(..)
        .noise_multiplier(1.0)
        .max_grad_norm(1.0)
        .logical_batch(32)
        .physical_batch(32)
        .build(sys)
        .unwrap();
    assert_eq!(private.backend_kind(), BackendKind::Native);
    assert_eq!(private.workers(), 2);
}

/// Satellite (PR 4): the noise-only logical step under data parallelism.
/// Poisson can select zero samples; the empty logical batch still runs
/// exactly one micro step (`micro_steps_for(0) == 1`), and driving it
/// through a 4-worker `DistributedStep` must add noise exactly once and
/// land on the same parameters as the single-worker path.
#[test]
fn empty_poisson_batch_noise_only_step_matches_single_worker() {
    use opacus_rs::data::LogicalBatch;
    use opacus_rs::distributed::{DistributedStep, ExecSpec, Parallelism};
    use opacus_rs::runtime::backend::native::model_for_task;
    use opacus_rs::runtime::backend::native::steps::{NativeAccumStep, NativeApplyStep};
    use opacus_rs::runtime::backend::{AccumExec, ApplyExec};
    use opacus_rs::runtime::step::HyperParams;
    use opacus_rs::trainer::BatchMemoryManager;
    use std::sync::Arc;

    let phys = 32;
    let mut bmm = BatchMemoryManager::with_workers(phys, phys, 4).unwrap();
    assert_eq!(bmm.micro_steps_for(0), 1, "empty batch still takes one step");
    let empty = LogicalBatch { indices: vec![] };
    let chunks = bmm.split(&empty);
    assert_eq!(chunks.len(), 1);
    assert!(chunks[0].is_empty());

    // mask-padded physical batch for the empty chunk
    let ds = opacus_rs::data::synth::synth_imdb(64, 3, 2000, 32);
    let batch = ds.gather(chunks[0], phys).unwrap();
    assert_eq!(batch.logical_size, 0);
    assert!(batch.mask.iter().all(|&m| m == 0.0));

    let model = Arc::new(model_for_task("embed").unwrap());
    let p = model.num_params();
    let params = model.init_params(5);
    let spec = ExecSpec {
        parallelism: Parallelism::Workers(4),
        seed: 2,
        ..Default::default()
    };
    let dist = DistributedStep::launch(model.clone(), phys, &spec).unwrap();

    // accumulation over an all-masked shard set must be exactly zero
    let out4 = AccumExec::run(&dist, &params, batch.x.clone(), &batch.y, &batch.mask, 1.0)
        .unwrap();
    assert!(out4.gsum.iter().all(|&g| g == 0.0), "masked grads must be zero");
    assert_eq!(out4.loss_sum, 0.0);
    assert_eq!(out4.snorm_sum, 0.0);
    let single = NativeAccumStep::new(model.clone(), phys);
    let out1 = AccumExec::run(&single, &params, batch.x, &batch.y, &batch.mask, 1.0).unwrap();
    assert_eq!(out1.gsum, out4.gsum);

    // one apply with the same root noise draw: the update is pure noise
    // and must be byte-identical across worker counts
    let noise: Vec<f32> = (0..p).map(|i| ((i % 17) as f32 - 8.0) * 0.01).collect();
    let hp = HyperParams {
        lr: 0.5,
        clip: 1.0,
        sigma: 1.1,
        denom: 32.0,
    };
    let p4 = ApplyExec::run(&dist, &params, &out4.gsum, &noise, hp).unwrap();
    let p1 = NativeApplyStep::new(p)
        .run(&params, &out1.gsum, &noise, hp)
        .unwrap();
    assert_eq!(p1, p4, "noise-only update must match the single-worker path");
    // noise was applied exactly once: p' = p − lr·σ·C·noise/denom
    for j in [0usize, 1, p / 2, p - 1] {
        let want = params[j] - 0.5 * (1.1 * 1.0 * noise[j]) / 32.0;
        assert!(
            (p4[j] - want).abs() < 1e-12,
            "param {j}: {} vs single noise application {want}",
            p4[j]
        );
    }
}

/// Satellite: `NoiseSource::Secure` must give fresh draws per engine
/// (OS entropy), while `Deterministic` reproduces the stream exactly.
#[test]
fn secure_noise_differs_while_deterministic_is_stable() {
    let draw = |secure: bool, deterministic: bool| -> Vec<f32> {
        let engine = PrivacyEngine::try_new(EngineConfig {
            secure_mode: secure,
            seed: 5,
            deterministic,
            ..Default::default()
        })
        .unwrap();
        let mut v = vec![0f32; 128];
        engine.sample_noise(&mut v);
        v
    };
    // secure mode, OS entropy: two engines must not share a stream
    assert_ne!(draw(true, false), draw(true, false), "secure draws must differ");
    // deterministic ChaCha20: bit-stable across engine instances (runs)
    assert_eq!(draw(true, true), draw(true, true), "deterministic draws must match");
    assert_eq!(draw(false, true), draw(false, true), "standard seeded draws must match");
}

/// DPDDP σ/√N noise splitting: opting in keeps training and accounting
/// intact (same ε bookkeeping — the accountant only sees σ), while the
/// injected noise actually perturbs the parameters.
#[test]
fn per_worker_noise_division_trains_and_accounts() {
    let build = |division: NoiseDivision| {
        let sys = Opacus::load_with_backend(
            "artifacts_that_do_not_exist",
            "embed",
            Backend::Native,
            128,
            32,
            3,
        )
        .unwrap();
        PrivacyEngine::private()
            .backend(Backend::Native)
            .noise(NoiseSource::Deterministic)
            .workers(2)
            .noise_division(division)
            .sampling(SamplingMode::Uniform)
            .noise_multiplier(1.0)
            .max_grad_norm(1.0)
            .logical_batch(32)
            .physical_batch(32)
            .seed(9)
            .build(sys)
            .unwrap()
    };
    let mut split = build(NoiseDivision::PerWorker);
    let mut root = build(NoiseDivision::Root);
    split.train_epoch().unwrap();
    root.train_epoch().unwrap();
    // identical ledger: ε only depends on (σ, q, steps)
    assert_eq!(
        split.epsilon(1e-5).unwrap(),
        root.epsilon(1e-5).unwrap(),
        "noise division must not change accounting"
    );
    // but the streams differ: per-worker shares vs the root draw
    assert_ne!(
        split.trainer.params, root.trainer.params,
        "per-worker shares are a different (equal-distribution) stream"
    );
}
