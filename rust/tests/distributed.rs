//! Distributed-execution integration tests — the PR-3 acceptance
//! criteria, on the always-on native backend:
//!
//! * worker parity: `workers = 4` vs `workers = 1` under the
//!   deterministic noise source spends the identical ε after 3 epochs
//!   and lands on parameters within 1e-6, for all four native tasks
//!   (both the fused and the virtual/BatchMemoryManager paths);
//! * noise sources: `Secure` draws differ across engine instances while
//!   `Deterministic` is stable across instances (stability across
//!   worker counts is the parity test above — rank-0 noise comes from
//!   the same engine stream whatever the pool size);
//! * DPDDP noise splitting: per-worker σ/√N mode trains and accounts.

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{
    Backend, BackendKind, EngineConfig, NoiseDivision, NoiseSource, PrivacyEngine, SamplingMode,
};

/// Train `task` for `epochs` epochs with `workers` threads under the
/// deterministic noise source; returns (ε, params, logical steps).
fn run_task(
    task: &str,
    workers: usize,
    epochs: usize,
    sampling: SamplingMode,
) -> (f64, Vec<f32>, u64) {
    let sys = Opacus::load_with_backend(
        "artifacts_that_do_not_exist",
        task,
        Backend::Native,
        192,
        32,
        11,
    )
    .unwrap();
    let mut private = PrivacyEngine::private()
        .backend(Backend::Native)
        .noise(NoiseSource::Deterministic)
        .workers(workers)
        .sampling(sampling)
        .noise_multiplier(0.8)
        .max_grad_norm(1.0)
        .lr(0.2)
        .logical_batch(32)
        .physical_batch(32)
        .seed(17)
        .build(sys)
        .unwrap();
    assert_eq!(private.backend_kind(), BackendKind::Native);
    assert_eq!(private.workers(), workers);
    private.train_epochs(epochs).unwrap();
    let eps = private.epsilon(1e-5).unwrap();
    let (trainer, _, _) = private.into_parts();
    let steps = trainer.global_step();
    (eps, trainer.params, steps)
}

fn worst_param_diff(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (*x as f64 - *y as f64).abs())
        .fold(0.0, f64::max)
}

/// The acceptance criterion: 4 workers vs 1 worker, deterministic noise,
/// 3 epochs, all four native tasks — identical ε, params within 1e-6.
/// Uniform sampling keeps logical == physical, so this exercises the
/// fused distributed path.
#[test]
fn workers4_matches_workers1_fused_all_tasks() {
    for task in ["mnist", "cifar", "embed", "lstm"] {
        let (e1, p1, s1) = run_task(task, 1, 3, SamplingMode::Uniform);
        let (e4, p4, s4) = run_task(task, 4, 3, SamplingMode::Uniform);
        assert_eq!(s1, s4, "{task}: step counts must match");
        assert_eq!(e1, e4, "{task}: ε must be identical, got {e1} vs {e4}");
        let worst = worst_param_diff(&p1, &p4);
        assert!(
            worst < 1e-6,
            "{task}: params diverged by {worst:.3e} between 1 and 4 workers"
        );
    }
}

/// The same guarantee through the virtual path: Poisson sampling routes
/// every logical step through accum chunks + one noisy apply, and the
/// BatchMemoryManager decomposition must stay worker-invariant too.
#[test]
fn workers4_matches_workers1_virtual_path() {
    for task in ["mnist", "embed"] {
        let (e1, p1, _) = run_task(task, 1, 3, SamplingMode::Poisson);
        let (e4, p4, _) = run_task(task, 4, 3, SamplingMode::Poisson);
        assert_eq!(e1, e4, "{task}: ε must be identical");
        let worst = worst_param_diff(&p1, &p4);
        assert!(
            worst < 1e-6,
            "{task}: virtual-path params diverged by {worst:.3e}"
        );
    }
}

/// `Backend::Auto` + a worker request must resolve to the native engine
/// (the only backend with a pool) rather than stranding the request on
/// whatever Auto would pick for single-threaded execution.
#[test]
fn auto_backend_with_workers_resolves_native() {
    let sys =
        Opacus::load_with_data("artifacts_that_do_not_exist", "embed", 96, 32, 1).unwrap();
    let private = PrivacyEngine::private()
        .workers(2) // note: no explicit .backend(..)
        .noise_multiplier(1.0)
        .max_grad_norm(1.0)
        .logical_batch(32)
        .physical_batch(32)
        .build(sys)
        .unwrap();
    assert_eq!(private.backend_kind(), BackendKind::Native);
    assert_eq!(private.workers(), 2);
}

/// Satellite: `NoiseSource::Secure` must give fresh draws per engine
/// (OS entropy), while `Deterministic` reproduces the stream exactly.
#[test]
fn secure_noise_differs_while_deterministic_is_stable() {
    let draw = |secure: bool, deterministic: bool| -> Vec<f32> {
        let engine = PrivacyEngine::try_new(EngineConfig {
            secure_mode: secure,
            seed: 5,
            deterministic,
            ..Default::default()
        })
        .unwrap();
        let mut v = vec![0f32; 128];
        engine.sample_noise(&mut v);
        v
    };
    // secure mode, OS entropy: two engines must not share a stream
    assert_ne!(draw(true, false), draw(true, false), "secure draws must differ");
    // deterministic ChaCha20: bit-stable across engine instances (runs)
    assert_eq!(draw(true, true), draw(true, true), "deterministic draws must match");
    assert_eq!(draw(false, true), draw(false, true), "standard seeded draws must match");
}

/// DPDDP σ/√N noise splitting: opting in keeps training and accounting
/// intact (same ε bookkeeping — the accountant only sees σ), while the
/// injected noise actually perturbs the parameters.
#[test]
fn per_worker_noise_division_trains_and_accounts() {
    let build = |division: NoiseDivision| {
        let sys = Opacus::load_with_backend(
            "artifacts_that_do_not_exist",
            "embed",
            Backend::Native,
            128,
            32,
            3,
        )
        .unwrap();
        PrivacyEngine::private()
            .backend(Backend::Native)
            .noise(NoiseSource::Deterministic)
            .workers(2)
            .noise_division(division)
            .sampling(SamplingMode::Uniform)
            .noise_multiplier(1.0)
            .max_grad_norm(1.0)
            .logical_batch(32)
            .physical_batch(32)
            .seed(9)
            .build(sys)
            .unwrap()
    };
    let mut split = build(NoiseDivision::PerWorker);
    let mut root = build(NoiseDivision::Root);
    split.train_epoch().unwrap();
    root.train_epoch().unwrap();
    // identical ledger: ε only depends on (σ, q, steps)
    assert_eq!(
        split.epsilon(1e-5).unwrap(),
        root.epsilon(1e-5).unwrap(),
        "noise division must not change accounting"
    );
    // but the streams differ: per-worker shares vs the root draw
    assert_ne!(
        split.trainer.params, root.trainer.params,
        "per-worker shares are a different (equal-distribution) stream"
    );
}
