//! Native-backend integration tests — always-on tier-1 coverage.
//!
//! Unlike `integration.rs` (which needs `make artifacts` and skips
//! otherwise), everything here runs on the pure-Rust execution backend:
//! `cargo test -q` exercises the full DP-SGD pipeline — per-sample
//! gradients, clipping, noise, virtual steps, accounting, eval — on a
//! machine with no artifacts and no XLA toolchain.
//!
//! Contents:
//! * per-layer parity: batched per-sample gradients vs a naive
//!   microbatch (batch-of-1 loop) oracle, within 1e-5;
//! * fused-native vs virtual-native: identical ε, near-identical params
//!   for a 512-logical / 64-physical decomposition;
//! * backend auto-selection: XLA when matching artifacts exist, native
//!   fallback otherwise;
//! * the end-to-end train ≥ 2 epochs + ε + eval acceptance path.

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{Backend, BackendKind, ClippingStrategy, PrivacyEngine, SamplingMode};
use opacus_rs::runtime::backend::native::layers::{Conv2d, Embedding, LayerNorm, Linear};
use opacus_rs::runtime::backend::native::model::{NativeModel, Op};
use opacus_rs::runtime::backend::{auto_backend_kind, resolve, ExecutionBackend};
use opacus_rs::runtime::tensor::{HostTensor, TensorData};

/// Slice one sample out of a batched tensor (microbatch oracle input).
fn sample_of(x: &HostTensor, s: usize) -> HostTensor {
    let per: usize = x.shape[1..].iter().product();
    let mut shape = vec![1usize];
    shape.extend_from_slice(&x.shape[1..]);
    match &x.data {
        TensorData::F32(v) => HostTensor::f32(shape, v[s * per..(s + 1) * per].to_vec()),
        TensorData::I32(v) => HostTensor::i32(shape, v[s * per..(s + 1) * per].to_vec()),
    }
}

/// Assert the batched per-sample gradients of `model` equal a batch-of-1
/// loop over the same samples, within `tol`.
fn assert_microbatch_parity(model: &NativeModel, x: &HostTensor, y: &[i32], tol: f64) {
    let b = y.len();
    let params = model.init_params(42);
    let mask = vec![1.0f32; b];
    let batched = model.per_sample_grads(&params, x, y, &mask).unwrap();
    let p = batched.num_params;
    for s in 0..b {
        let xs = sample_of(x, s);
        let single = model
            .per_sample_grads(&params, &xs, &y[s..s + 1], &[1.0])
            .unwrap();
        let got = &batched.gsample[s * p..(s + 1) * p];
        let want = &single.gsample[..p];
        let mut worst = 0.0f64;
        for (a, b_) in got.iter().zip(want.iter()) {
            worst = worst.max((*a as f64 - *b_ as f64).abs());
        }
        assert!(
            worst <= tol,
            "sample {s}: batched vs microbatch grads differ by {worst:.3e} (> {tol:.0e})"
        );
        assert!(
            (batched.losses[s] - single.losses[0]).abs() <= tol,
            "sample {s}: loss {} vs {}",
            batched.losses[s],
            single.losses[0]
        );
    }
}

fn f32_batch(shape: Vec<usize>, seed: u64) -> HostTensor {
    use opacus_rs::rng::{gaussian, pcg::Xoshiro256pp};
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let n = shape.iter().product();
    let mut v = vec![0f32; n];
    gaussian::fill_standard_normal(&mut rng, &mut v);
    HostTensor::f32(shape, v)
}

#[test]
fn parity_linear_batched_vs_microbatch() {
    let m = NativeModel::new(
        "parity_linear",
        vec![6],
        "f32",
        3,
        None,
        vec![Op::Layer(Box::new(Linear::new(6, 3)))],
    )
    .unwrap();
    let x = f32_batch(vec![5, 6], 1);
    assert_microbatch_parity(&m, &x, &[0, 2, 1, 1, 0], 1e-5);
}

#[test]
fn parity_conv2d_batched_vs_microbatch() {
    let m = NativeModel::new(
        "parity_conv",
        vec![6, 6, 2],
        "f32",
        3,
        None,
        vec![
            Op::Layer(Box::new(Conv2d::new(2, 3, 3, 2, 1))), // [3,3,3]
            Op::Relu,
            Op::Flatten,
            Op::Layer(Box::new(Linear::new(27, 3))),
        ],
    )
    .unwrap();
    let x = f32_batch(vec![4, 6, 6, 2], 2);
    assert_microbatch_parity(&m, &x, &[2, 0, 1, 2], 1e-5);
}

#[test]
fn parity_embedding_batched_vs_microbatch() {
    let m = NativeModel::new(
        "parity_embed",
        vec![5],
        "i32",
        2,
        Some(7),
        vec![
            Op::Layer(Box::new(Embedding::new(7, 4))),
            Op::MeanPool,
            Op::Layer(Box::new(Linear::new(4, 2))),
        ],
    )
    .unwrap();
    // repeated tokens inside and across samples (accumulation paths)
    let x = HostTensor::i32(
        vec![4, 5],
        vec![0, 1, 1, 6, 3, 2, 2, 2, 2, 2, 5, 4, 3, 2, 1, 6, 6, 0, 0, 1],
    );
    assert_microbatch_parity(&m, &x, &[0, 1, 1, 0], 1e-5);
}

#[test]
fn parity_layernorm_batched_vs_microbatch() {
    let m = NativeModel::new(
        "parity_ln",
        vec![8],
        "f32",
        3,
        None,
        vec![
            Op::Layer(Box::new(LayerNorm::new(8))),
            Op::Layer(Box::new(Linear::new(8, 3))),
        ],
    )
    .unwrap();
    let x = f32_batch(vec![6, 8], 3);
    assert_microbatch_parity(&m, &x, &[0, 1, 2, 0, 1, 2], 1e-5);
}

#[test]
fn parity_full_task_models() {
    // the per-task stacks themselves (conv+conv+linear+linear, etc.)
    use opacus_rs::runtime::backend::native::model_for_task;
    let m = model_for_task("mnist").unwrap();
    let ds = opacus_rs::data::synth::synth_mnist(3, 9);
    let b = ds.gather(&[0, 1, 2], 3).unwrap();
    assert_microbatch_parity(&m, &b.x, &b.y, 1e-5);

    // the true recurrent LSTM stack: per-sample BPTT vs batch-of-1 oracle
    let m = model_for_task("lstm").unwrap();
    let ds = opacus_rs::data::synth::synth_imdb(3, 9, 4000, 64);
    let b = ds.gather(&[0, 1, 2], 3).unwrap();
    assert_microbatch_parity(&m, &b.x, &b.y, 1e-5);

    // the attention stack: per-sample grads through the softmax
    let m = model_for_task("attn").unwrap();
    let ds = opacus_rs::data::synth::synth_imdb(3, 9, 2000, 32);
    let b = ds.gather(&[0, 1, 2], 3).unwrap();
    assert_microbatch_parity(&m, &b.x, &b.y, 1e-5);
}

/// GRU has no synth task of its own; its microbatch-oracle parity runs
/// on a hand-built stack (the acceptance criterion covers all three new
/// kernels: lstm, gru, mha). Since PR 5 this batch runs through the
/// blocked gemm engine, so the oracle also pins the blocked path.
#[test]
fn parity_gru_batched_vs_microbatch() {
    use opacus_rs::runtime::backend::native::Gru;
    let m = NativeModel::new(
        "parity_gru",
        vec![5, 3], // T = 5, D = 3
        "f32",
        2,
        None,
        vec![
            Op::Layer(Box::new(Gru::new(3, 4))),
            Op::MeanPool,
            Op::Layer(Box::new(Linear::new(4, 2))),
        ],
    )
    .unwrap();
    let x = f32_batch(vec![4, 5, 3], 5);
    assert_microbatch_parity(&m, &x, &[0, 1, 1, 0], 1e-5);
}

/// Satellite (PR 5): the generic tanh RNN kernel rides the same batched
/// projections as LSTM/GRU from day one — microbatch-oracle parity on a
/// hand-built stack, like GRU.
#[test]
fn parity_rnn_batched_vs_microbatch() {
    use opacus_rs::runtime::backend::native::Rnn;
    let m = NativeModel::new(
        "parity_rnn",
        vec![5, 3], // T = 5, D = 3
        "f32",
        2,
        None,
        vec![
            Op::Layer(Box::new(Rnn::new(3, 4))),
            Op::MeanPool,
            Op::Layer(Box::new(Linear::new(4, 2))),
        ],
    )
    .unwrap();
    let x = f32_batch(vec![4, 5, 3], 6);
    assert_microbatch_parity(&m, &x, &[1, 0, 1, 0], 1e-5);
    // the validator's rnn row accepts the kernel's kind string
    let meta = opacus_rs::runtime::artifact::ModelMeta {
        task: "parity_rnn".into(),
        num_params: m.num_params(),
        input_shape: vec![5, 3],
        input_dtype: "f32".into(),
        num_classes: 2,
        layer_kinds: m.layer_kinds(),
        vocab: None,
        init_file: String::new(),
    };
    assert!(opacus_rs::privacy::validator::validate_model(&meta).is_empty());
}

/// Acceptance (PR 4): fused-native vs virtual-native ε/param parity for
/// the recurrent and attention tasks, single-threaded AND on a 4-worker
/// pool — the new kernels must be decomposition- and shard-invariant.
#[test]
fn fused_vs_virtual_parity_lstm_attn_across_workers() {
    use opacus_rs::privacy::NoiseSource;
    for task in ["lstm", "attn"] {
        for workers in [1usize, 4] {
            let run = |physical: usize| {
                let sys = Opacus::load_with_backend(
                    "artifacts_that_do_not_exist",
                    task,
                    Backend::Native,
                    256,
                    32,
                    7,
                )
                .unwrap();
                let mut private = PrivacyEngine::private()
                    .backend(Backend::Native)
                    .noise(NoiseSource::Deterministic)
                    .workers(workers)
                    .sampling(SamplingMode::Uniform)
                    .noise_multiplier(0.8)
                    .max_grad_norm(1.0)
                    .lr(0.2)
                    .logical_batch(128)
                    .physical_batch(physical)
                    .seed(13)
                    .build(sys)
                    .unwrap();
                assert_eq!(private.workers(), workers);
                private.train_epoch().unwrap(); // 256/128 = 2 logical steps
                let eps = private.epsilon(1e-5).unwrap();
                let (trainer, _, _) = private.into_parts();
                (eps, trainer.params)
            };
            let (eps_fused, p_fused) = run(128); // logical == physical
            let (eps_virtual, p_virtual) = run(32); // 4 micro-steps/logical
            assert_eq!(
                eps_fused, eps_virtual,
                "{task} w={workers}: ε must be identical"
            );
            let mut worst = 0.0f64;
            for (a, b) in p_fused.iter().zip(p_virtual.iter()) {
                worst = worst.max((*a as f64 - *b as f64).abs());
            }
            assert!(
                worst < 1e-4,
                "{task} w={workers}: fused vs virtual params diverged by {worst:.3e}"
            );
        }
    }
}

/// Per-layer clipping on the native backend against the microbatch
/// oracle: the builder resolves `ClippingStrategy::PerLayer` to the one
/// effective scalar C/√L, and the batched pipeline at that scalar must
/// equal a batch-of-1 loop at the same scalar — while every clipped
/// sample respects the per-layer budget (‖clip(g)‖ ≤ C/√L, so the total
/// L2 sensitivity stays ≤ C).
#[test]
fn per_layer_clipping_matches_microbatch_oracle() {
    use opacus_rs::runtime::backend::native::model::l2_norm;
    use opacus_rs::runtime::backend::native::model_for_task;

    let m = model_for_task("lstm").unwrap(); // embedding + lstm + linear
    let num_layers = m.layer_kinds().len();
    assert!(num_layers >= 2, "needs a genuinely multi-layer stack");
    let c = 1.0f64;
    let eff = ClippingStrategy::PerLayer.effective_clip(c, num_layers) as f32;
    // the budget split preserves sensitivity: √(L · (C/√L)²) = C
    assert!((eff as f64 * (num_layers as f64).sqrt() - c).abs() < 1e-6);

    let b = 5;
    let ds = opacus_rs::data::synth::synth_imdb(b, 3, 4000, 64);
    let idx: Vec<usize> = (0..b).collect();
    let batch = ds.gather(&idx, b).unwrap();
    let params = m.init_params(42);
    let full = m.dp_grad(&params, &batch.x, &batch.y, &batch.mask, eff).unwrap();
    assert_eq!(full.real, b);

    let p = m.num_params();
    let mut oracle = vec![0f64; p];
    for s in 0..b {
        let xs = sample_of(&batch.x, s);
        let one = m
            .dp_grad(&params, &xs, &batch.y[s..s + 1], &[1.0], eff)
            .unwrap();
        // each clipped per-sample gradient obeys the per-layer budget
        assert!(
            l2_norm(&one.gsum) <= eff as f64 + 1e-6,
            "sample {s}: clipped norm {} above C/√L = {eff}",
            l2_norm(&one.gsum)
        );
        for (acc, &g) in oracle.iter_mut().zip(one.gsum.iter()) {
            *acc += g as f64;
        }
    }
    let mut worst = 0.0f64;
    for (got, want) in full.gsum.iter().zip(oracle.iter()) {
        worst = worst.max((*got as f64 - want).abs());
    }
    assert!(
        worst <= 1e-5,
        "per-layer batched vs microbatch oracle differ by {worst:.3e}"
    );
}

/// Fused (one 512-wide step) and virtual (8 × 64 accumulation chunks)
/// native execution must spend the identical ε and land on near-identical
/// parameters — the BatchMemoryManager decomposition is semantics-free.
#[test]
fn fused_native_vs_virtual_native_512_over_64() {
    let run = |physical: usize| {
        let sys =
            Opacus::load_with_backend("artifacts", "embed", Backend::Native, 1024, 64, 7)
                .unwrap();
        let mut private = PrivacyEngine::private()
            .backend(Backend::Native)
            .sampling(SamplingMode::Uniform)
            .noise_multiplier(1.0)
            .max_grad_norm(1.0)
            .lr(0.2)
            .logical_batch(512)
            .physical_batch(physical)
            .seed(13)
            .build(sys)
            .unwrap();
        assert_eq!(private.backend_kind(), BackendKind::Native);
        private.train_epoch().unwrap(); // 1024/512 = 2 logical steps
        let eps = private.epsilon(1e-5).unwrap();
        let (trainer, _, _) = private.into_parts();
        (eps, trainer)
    };

    let (eps_fused, fused) = run(512); // logical == physical: fused mode
    let (eps_virtual, virtual_) = run(64); // 8 micro-steps per logical step
    assert!(fused.memory_manager().is_none(), "512/512 must run fused");
    let bmm = virtual_.memory_manager().expect("512/64 must run virtual");
    assert_eq!(bmm.logical_steps(), 2);
    assert_eq!(bmm.micro_steps(), 16);
    assert!((bmm.amplification() - 8.0).abs() < 1e-9);

    assert!(
        (eps_fused - eps_virtual).abs() < 1e-12,
        "ε must be identical: fused {eps_fused} vs virtual {eps_virtual}"
    );
    assert_eq!(fused.params.len(), virtual_.params.len());
    let mut worst = 0.0f64;
    for (a, b) in fused.params.iter().zip(virtual_.params.iter()) {
        worst = worst.max((*a as f64 - *b as f64).abs());
    }
    assert!(
        worst < 1e-4,
        "params diverged by {worst:.3e} between fused and virtual execution"
    );
}

/// The acceptance path: full DP-SGD (train ≥ 2 epochs, ε accounted,
/// eval) with zero artifact skips, on a machine with no `make artifacts`
/// output at all.
#[test]
fn native_end_to_end_trains_accounts_and_evals() {
    let sys = Opacus::load_with_data("artifacts_that_do_not_exist", "mnist", 256, 64, 7).unwrap();
    assert_eq!(sys.backend_kind(), BackendKind::Native);
    let mut private = PrivacyEngine::private()
        .noise_multiplier(0.8)
        .max_grad_norm(1.2)
        .lr(0.3)
        .logical_batch(64)
        .physical_batch(32) // exercises the BatchMemoryManager too
        .seed(3)
        .build(sys)
        .unwrap();
    let losses = private.train_epochs(2).unwrap();
    assert_eq!(losses.len(), 2);
    assert!(losses.iter().all(|l| l.is_finite()));
    assert_eq!(private.global_step(), 8); // ceil(1/q) = 4 per epoch × 2
    let eps = private.epsilon(1e-5).unwrap();
    assert!(eps > 0.0 && eps.is_finite(), "ε must be accounted, got {eps}");
    let (eval_loss, acc) = private.evaluate().unwrap();
    assert!(eval_loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
}

/// Uniform fused native training learns the synthetic task (loss ↓).
#[test]
fn native_fused_training_reduces_loss() {
    let sys = Opacus::load_with_backend("artifacts", "mnist", Backend::Native, 256, 64, 1)
        .unwrap();
    let mut private = PrivacyEngine::private()
        .backend(Backend::Native)
        .sampling(SamplingMode::Uniform)
        .noise_multiplier(0.4)
        .max_grad_norm(1.0)
        .lr(0.3)
        .logical_batch(32)
        .physical_batch(32)
        .seed(5)
        .build(sys)
        .unwrap();
    let losses = private.train_epochs(4).unwrap();
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "native DP training did not reduce loss: {losses:?}"
    );
}

/// Per-layer clipping and the GDP accountant work natively too.
#[test]
fn native_per_layer_clipping_and_gdp() {
    use opacus_rs::privacy::AccountantKind;
    let sys = Opacus::load_with_backend("artifacts", "embed", Backend::Native, 128, 32, 2)
        .unwrap();
    let num_layers = sys.model.layer_kinds.len();
    let mut private = PrivacyEngine::private()
        .backend(Backend::Native)
        .accountant(AccountantKind::Gdp)
        .clipping(ClippingStrategy::PerLayer)
        .noise_multiplier(1.0)
        .max_grad_norm(1.0)
        .seed(6)
        .build(sys)
        .unwrap();
    let want = 1.0 / (num_layers as f64).sqrt();
    assert!((private.optimizer.effective_clip - want).abs() < 1e-12);
    assert_eq!(private.engine().accountant_mechanism(), "gdp");
    assert!(private.train_epoch().unwrap().is_finite());
    assert!(private.epsilon(1e-5).unwrap() > 0.0);
}

/// Backend auto-selection: a registry with a matching on-disk artifact
/// selects XLA; anything less falls back to the native engine.
#[test]
fn backend_auto_selection_matrix() {
    use opacus_rs::util::npy::NpyArray;
    let dir = std::env::temp_dir().join(format!(
        "opacus_rs_auto_matrix_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();

    // 1. no directory at all → native
    assert_eq!(auto_backend_kind(&dir, "mnist"), BackendKind::Native);

    // 2. manifest with model + artifact entry, but nothing on disk → native
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = r#"{
      "version": 1,
      "models": {
        "mnist": {"num_params": 3, "input_shape": [2], "input_dtype": "f32",
                  "num_classes": 2, "layer_kinds": ["linear"], "vocab": null,
                  "init_file": "mnist_init.npy"}
      },
      "artifacts": [
        {"name": "mnist_accum_b8", "file": "mnist_accum_b8.hlo.txt",
         "kind": "train", "variant": "accum", "task": "mnist", "batch": 8,
         "num_params": 3, "inputs": [], "outputs": []}
      ],
      "goldens": []
    }"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    assert_eq!(auto_backend_kind(&dir, "mnist"), BackendKind::Native);

    // 3. artifact on disk → XLA for this task when a PJRT client exists
    //    (under the xla-stub build the client is unavailable, so Auto
    //    must still protect the run by staying native), native for other
    //    tasks either way
    std::fs::write(dir.join("mnist_accum_b8.hlo.txt"), "stub").unwrap();
    NpyArray::f32(vec![3], vec![0.1, 0.2, 0.3])
        .write(&dir.join("mnist_init.npy"))
        .unwrap();
    use opacus_rs::runtime::backend::xla::XlaBackend;
    assert!(XlaBackend::artifacts_present(&dir, "mnist"));
    assert!(!XlaBackend::artifacts_present(&dir, "embed"));
    let xla_expected = if opacus_rs::runtime::client::available() {
        BackendKind::Xla
    } else {
        BackendKind::Native
    };
    assert_eq!(auto_backend_kind(&dir, "mnist"), xla_expected);
    assert_eq!(auto_backend_kind(&dir, "embed"), BackendKind::Native);

    // resolve() agrees and yields working backends
    let b = resolve(&dir, "mnist", Backend::Auto).unwrap();
    assert_eq!(b.kind(), xla_expected);
    if b.kind() == BackendKind::Xla {
        assert_eq!(b.init_params().unwrap().len(), 3);
    }
    let b = resolve(&dir, "embed", Backend::Auto).unwrap();
    assert_eq!(b.kind(), BackendKind::Native);

    // 4. the `opacus inspect` surface: descriptions name the backend
    let mnist_desc = resolve(&dir, "mnist", Backend::Auto).unwrap().describe();
    assert!(mnist_desc.contains(&xla_expected.to_string()), "{mnist_desc}");
    assert!(resolve(&dir, "embed", Backend::Auto).unwrap().describe().contains("native"));

    std::fs::remove_dir_all(&dir).ok();
}

/// Poisson sampling (variable logical batches, possibly empty) is safe
/// on the native path: noise-only steps still run and account.
#[test]
fn native_poisson_with_tiny_q() {
    let sys = Opacus::load_with_backend("artifacts", "embed", Backend::Native, 128, 32, 4)
        .unwrap();
    let mut private = PrivacyEngine::private()
        .backend(Backend::Native)
        .sampling(SamplingMode::Poisson)
        .noise_multiplier(1.0)
        .max_grad_norm(1.0)
        .logical_batch(4) // q = 1/32: empty logical batches are likely
        .physical_batch(8)
        .seed(8)
        .build(sys)
        .unwrap();
    private.train_epoch().unwrap();
    assert_eq!(private.global_step() as usize, private.loader.steps_per_epoch);
    assert!(private.epsilon(1e-5).unwrap() > 0.0);
}
