//! Ghost-clipping integration tests — the PR-9 acceptance criteria:
//!
//! * parity: `--clipping ghost` (two-pass norm-only backward + weighted
//!   second backward) spends a bitwise-identical ε and lands on
//!   parameters within 1e-6 of the materializing `flat` path on every
//!   native task, under the deterministic noise source;
//! * the parity is execution-shape invariant: 1 vs 4 workers, pipeline
//!   on vs off, all agree with the single-worker materializing run;
//! * the memory story: the `transformer` task (~10M params) refuses to
//!   build the materializing step at batch 32 — the `[B, P]` gradient
//!   matrix is over `OPACUS_MATERIALIZE_CAP` — and the error points at
//!   `--clipping ghost`, which then trains the same batch in O(B) norm
//!   state.

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{
    Backend, BackendKind, ClippingStrategy, NoiseSource, PrivacyEngine, SamplingMode,
};

/// Train `task` for `epochs` epochs under the deterministic noise
/// source with the given clipping strategy and execution shape;
/// returns (ε, params).
fn run_task(
    task: &str,
    clipping: ClippingStrategy,
    workers: usize,
    pipeline: Option<usize>,
    epochs: usize,
) -> (f64, Vec<f32>) {
    let sys = Opacus::load_with_backend(
        "artifacts_that_do_not_exist",
        task,
        Backend::Native,
        192,
        32,
        11,
    )
    .unwrap();
    let mut b = PrivacyEngine::private()
        .backend(Backend::Native)
        .noise(NoiseSource::Deterministic)
        .clipping(clipping)
        .workers(workers)
        .sampling(SamplingMode::Uniform)
        .noise_multiplier(0.8)
        .max_grad_norm(1.0)
        .lr(0.2)
        .logical_batch(32)
        .physical_batch(32)
        .seed(17);
    if let Some(depth) = pipeline {
        b = b.pipeline(depth);
    }
    let mut private = b.build(sys).unwrap();
    assert_eq!(private.backend_kind(), BackendKind::Native);
    private.train_epochs(epochs).unwrap();
    let eps = private.epsilon(1e-5).unwrap();
    let (trainer, _, _) = private.into_parts();
    (eps, trainer.params)
}

fn worst_param_diff(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (*x as f64 - *y as f64).abs())
        .fold(0.0, f64::max)
}

/// The headline parity: on every pre-existing native task, ghost and
/// materializing clipping spend the *bitwise-identical* ε (the ledger
/// only sees σ, q, and steps — the clipper never enters it) and agree
/// on parameters within 1e-6 after two epochs.
#[test]
fn ghost_matches_flat_all_tasks() {
    for task in ["mnist", "cifar", "embed", "lstm", "attn"] {
        let (e_flat, p_flat) = run_task(task, ClippingStrategy::Flat, 1, None, 2);
        let (e_ghost, p_ghost) = run_task(task, ClippingStrategy::Ghost, 1, None, 2);
        assert_eq!(
            e_flat.to_bits(),
            e_ghost.to_bits(),
            "{task}: ε must be bitwise identical, got {e_flat} vs {e_ghost}"
        );
        let worst = worst_param_diff(&p_flat, &p_ghost);
        assert!(
            worst < 1e-6,
            "{task}: ghost params diverged from flat by {worst:.3e}"
        );
    }
}

/// Ghost is execution-shape invariant: 4 workers and the pipelined
/// step family must land where the single-worker materializing run
/// lands, with the identical ε.
#[test]
fn ghost_matches_flat_across_workers_and_pipeline() {
    for task in ["embed", "attn"] {
        let (e_ref, p_ref) = run_task(task, ClippingStrategy::Flat, 1, None, 2);
        let shapes: [(usize, Option<usize>); 3] = [(1, None), (4, None), (1, Some(2))];
        for (workers, pipeline) in shapes {
            let (e, p) = run_task(task, ClippingStrategy::Ghost, workers, pipeline, 2);
            assert_eq!(
                e_ref.to_bits(),
                e.to_bits(),
                "{task}: ε drifted at workers={workers} pipeline={pipeline:?}"
            );
            let worst = worst_param_diff(&p_ref, &p);
            assert!(
                worst < 1e-6,
                "{task}: params diverged by {worst:.3e} at workers={workers} \
                 pipeline={pipeline:?}"
            );
        }
    }
}

/// The reason ghost exists: the transformer task's `[32, 10.5M]` f32
/// per-sample gradient matrix is over the 1 GiB materialization cap, so
/// the flat build is a typed error naming the escape hatch — and the
/// ghost build trains that exact batch.
#[test]
fn transformer_trains_with_ghost_but_flat_hits_the_cap() {
    let build = |clipping: ClippingStrategy| {
        let sys = Opacus::load_with_backend(
            "artifacts_that_do_not_exist",
            "transformer",
            Backend::Native,
            64,
            32,
            11,
        )
        .unwrap();
        PrivacyEngine::private()
            .backend(Backend::Native)
            .noise(NoiseSource::Deterministic)
            .clipping(clipping)
            .sampling(SamplingMode::Uniform)
            .noise_multiplier(1.0)
            .max_grad_norm(1.0)
            .lr(0.1)
            .logical_batch(32)
            .physical_batch(32)
            .seed(3)
            .build(sys)
    };

    let msg = match build(ClippingStrategy::Flat) {
        Ok(_) => panic!("flat must refuse to build the transformer step at batch 32"),
        Err(e) => format!("{e:#}"),
    };
    assert!(
        msg.contains("OPACUS_MATERIALIZE_CAP"),
        "cap error must name the cap env var, got: {msg}"
    );
    assert!(
        msg.contains("--clipping ghost"),
        "cap error must point at the ghost escape hatch, got: {msg}"
    );

    let mut private = build(ClippingStrategy::Ghost).expect("ghost must build past the cap");
    private.train_epoch().unwrap();
    let eps = private.epsilon(1e-5).unwrap();
    assert!(eps.is_finite() && eps > 0.0, "ghost transformer must account, got ε = {eps}");
    let (trainer, _, _) = private.into_parts();
    assert!(
        trainer.params.iter().all(|p| p.is_finite()),
        "ghost transformer step produced non-finite params"
    );
}
