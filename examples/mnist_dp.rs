//! End-to-end validation driver (EXPERIMENTS.md §E2E): train the MNIST
//! CNN (the paper's Table-1a model) with DP-SGD for a few hundred steps
//! on the synthetic-MNIST corpus, log the loss curve, the privacy
//! trajectory and held-out accuracy, and write everything to
//! results/mnist_dp_run.json.
//!
//! σ is calibrated for a target budget of (ε = 3.0, δ = 1e-5) through the
//! builder's `.target_epsilon` — the `make_private_with_epsilon` path.
//!
//! `--backend auto` (default) runs on XLA artifacts when they exist and
//! on the native per-sample-gradient engine otherwise.
//!
//! Run: cargo run --release --example mnist_dp [-- --epochs 12
//!      --train 2048 --batch 64 --eps 3.0 --secure --backend native]

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{Backend, NoiseSource, PrivacyEngine, SamplingMode};
use opacus_rs::util::cli::Args;
use opacus_rs::util::json::Json;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["secure", "uniform"])?;
    let epochs = args.get_usize("epochs", 12)?;
    let n_train = args.get_usize("train", 2048)?;
    let batch = args.get_usize("batch", 64)?;
    let target_eps = args.get_f64("eps", 3.0)?;
    let delta = args.get_f64("delta", 1e-5)?;
    let lr = args.get_f64("lr", 0.25)?;
    let backend: Backend = args.get_or("backend", "auto").parse()?;

    println!("== opacus-rs end-to-end driver: MNIST CNN ==");
    let sys = Opacus::load_with_backend("artifacts", "mnist", backend, n_train, 512, 0)?;
    println!("execution backend: {}", sys.backend_description());

    let mut trainer = PrivacyEngine::private()
        .backend(backend)
        .noise(if args.has_flag("secure") {
            NoiseSource::Deterministic
        } else {
            NoiseSource::Standard
        })
        .sampling(if args.has_flag("uniform") {
            SamplingMode::Uniform
        } else {
            SamplingMode::Poisson
        })
        .max_grad_norm(1.0)
        .lr(lr)
        .logical_batch(batch)
        .physical_batch(64)
        .seed(42)
        .target_epsilon(target_eps, delta, epochs)
        .build(sys)?
        .into_trainer();
    println!(
        "calibrated σ = {:.3} for (ε={target_eps}, δ={delta}) over {} steps \
         (q = {:.4}, Poisson sampling)",
        trainer.current_sigma(),
        epochs * trainer.steps_per_epoch(),
        trainer.sample_rate(),
    );

    let mut curve = Vec::new();
    for epoch in 0..epochs {
        let loss = trainer.train_epoch()?;
        let eps = trainer.epsilon(delta)?;
        let snorm = trainer
            .metrics
            .records
            .last()
            .map(|r| r.snorm)
            .unwrap_or(f64::NAN);
        println!(
            "epoch {epoch:>3}: loss = {loss:.4}  ε = {eps:.3}  mean ‖g‖ = {snorm:.3}  \
             steps = {}",
            trainer.global_step()
        );
        curve.push((epoch, loss, eps));
    }

    let (eval_loss, acc) = trainer.evaluate()?;
    let final_eps = trainer.epsilon(delta)?;
    println!("----------------------------------------------");
    println!("steps trained      : {}", trainer.global_step());
    println!("final train loss   : {:.4}", curve.last().unwrap().1);
    println!("held-out loss/acc  : {eval_loss:.4} / {:.1}%", acc * 100.0);
    println!("privacy spent      : (ε = {final_eps:.3}, δ = {delta})");
    assert!(
        final_eps <= target_eps * 1.01,
        "budget violated: {final_eps} > {target_eps}"
    );

    // persist the run for EXPERIMENTS.md
    std::fs::create_dir_all("results").ok();
    let j = Json::obj(vec![
        ("task", Json::str("mnist")),
        ("epochs", Json::num(epochs as f64)),
        ("steps", Json::num(trainer.global_step() as f64)),
        ("sigma", Json::num(trainer.current_sigma())),
        ("target_eps", Json::num(target_eps)),
        ("final_eps", Json::num(final_eps)),
        ("final_loss", Json::num(curve.last().unwrap().1)),
        ("eval_loss", Json::num(eval_loss)),
        ("eval_accuracy", Json::num(acc)),
        (
            "loss_curve",
            Json::Arr(
                curve
                    .iter()
                    .map(|&(e, l, eps)| {
                        Json::obj(vec![
                            ("epoch", Json::num(e as f64)),
                            ("loss", Json::num(l)),
                            ("eps", Json::num(eps)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("results/mnist_dp_run.json", j.to_string())?;
    trainer.metrics.save(std::path::Path::new("results/mnist_dp_metrics.json"))?;
    println!("run record -> results/mnist_dp_run.json");
    Ok(())
}
