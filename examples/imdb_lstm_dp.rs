//! DP training of the IMDb LSTM task (the paper's hardest Table-1
//! model): per-sample gradients through the sequence model, and the
//! `BatchMemoryManager` virtualizing a logical batch of 128 over
//! physical batches of 64.
//!
//! Both backends run a true recurrent LSTM: the XLA path executes the
//! AOT artifacts, and the native engine runs its own time-unrolled
//! per-sample-BPTT kernel (embedding → lstm → meanpool → linear) — the
//! printed layer kinds name the recurrent layer either way.
//!
//! Run: cargo run --release --example imdb_lstm_dp [-- --epochs 4
//!      --train 512 --sigma 0.8 --backend native]

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{Backend, PrivacyEngine};
use opacus_rs::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let epochs = args.get_usize("epochs", 4)?;
    let n_train = args.get_usize("train", 512)?;
    let sigma = args.get_f64("sigma", 0.8)?;
    let backend: Backend = args.get_or("backend", "auto").parse()?;

    println!("== opacus-rs: IMDb LSTM task, DP-SGD ==");
    let sys = Opacus::load_with_backend("artifacts", "lstm", backend, n_train, 128, 1)?;
    println!("execution backend: {}", sys.backend_description());
    println!(
        "model: vocab {:?}, input {:?}, layers {:?}, {} params",
        sys.model.vocab, sys.model.input_shape, sys.model.layer_kinds, sys.model.num_params
    );

    // logical batch 128 over physical 64: the batch memory manager runs
    // each logical step as ~2 accumulation micro-steps
    let mut private = PrivacyEngine::private()
        .backend(backend)
        .noise_multiplier(sigma)
        .max_grad_norm(1.0)
        .lr(0.4)
        .logical_batch(128)
        .physical_batch(64)
        .seed(17)
        .build(sys)?;

    for epoch in 0..epochs {
        let loss = private.train_epoch()?;
        println!(
            "epoch {epoch}: loss = {loss:.4}  ε = {:.3}",
            private.epsilon(1e-5)?
        );
    }
    let (eval_loss, acc) = private.evaluate()?;
    println!(
        "held-out: loss = {eval_loss:.4}, accuracy = {:.1}% (2-class)",
        acc * 100.0
    );
    if let Some(bmm) = private.memory_manager() {
        println!(
            "batch memory manager: {} logical steps -> {} micro steps \
             (amplification {:.2}x, peak logical batch {})",
            bmm.logical_steps(),
            bmm.micro_steps(),
            bmm.amplification(),
            bmm.peak_logical_batch()
        );
    }
    Ok(())
}
