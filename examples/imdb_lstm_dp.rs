//! DP training of the IMDb LSTM (1,081,002 params — the paper's hardest
//! Table-1 model): embedding + custom LSTM + classifier head, per-sample
//! gradients through the recurrence, virtual steps over physical batches
//! of 64.
//!
//! Run: cargo run --release --example imdb_lstm_dp [-- --epochs 4
//!      --train 512 --sigma 0.8]

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{EngineConfig, PrivacyEngine, PrivacyParams};
use opacus_rs::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let epochs = args.get_usize("epochs", 4)?;
    let n_train = args.get_usize("train", 512)?;
    let sigma = args.get_f64("sigma", 0.8)?;

    println!("== opacus-rs: IMDb LSTM (1,081,002 params), DP-SGD ==");
    let sys = Opacus::load_with_data("artifacts", "lstm", n_train, 128, 1)?;
    println!(
        "model: vocab {:?}, input {:?}, layers {:?}",
        sys.model.vocab, sys.model.input_shape, sys.model.layer_kinds
    );

    let engine = PrivacyEngine::new(EngineConfig {
        seed: 17,
        ..Default::default()
    });
    // logical batch 128 over physical 64 => 2 virtual micro-steps/step
    let pp = PrivacyParams::new(sigma, 1.0)
        .with_lr(0.4)
        .with_batches(128, 64);
    let mut trainer = engine.make_private(sys, pp)?;

    for epoch in 0..epochs {
        let loss = trainer.train_epoch()?;
        println!(
            "epoch {epoch}: loss = {loss:.4}  ε = {:.3}",
            trainer.epsilon(1e-5)?
        );
    }
    let (eval_loss, acc) = trainer.evaluate()?;
    println!(
        "held-out: loss = {eval_loss:.4}, accuracy = {:.1}% (2-class)",
        acc * 100.0
    );
    Ok(())
}
