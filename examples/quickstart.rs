//! Quickstart — the paper's §2 usage example, two Opacus lines included.
//!
//!     dataset = Dataset(); model = Net(); optimizer = SGD(...)
//!     privacy_engine = PrivacyEngine()                     # line 1
//!     model, optimizer, data_loader = privacy_engine.make_private(...)  # line 2
//!     # Now it's business as usual
//!
//! Run: cargo run --release --example quickstart

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{PrivacyEngine, PrivacyParams};

fn main() -> anyhow::Result<()> {
    // dataset + model + optimizer: one loaded system (AOT artifacts)
    let sys = Opacus::load("artifacts", "mnist")?;

    // the two Opacus lines:
    let privacy_engine = PrivacyEngine::default();
    let mut trainer = privacy_engine.make_private(
        sys,
        PrivacyParams::new(/* noise_multiplier */ 1.1, /* max_grad_norm */ 1.0)
            .with_lr(0.25)
            .with_batches(/* logical */ 64, /* physical */ 64),
    )?;

    // now it's business as usual
    for epoch in 0..3 {
        let loss = trainer.train_epoch()?;
        let eps = trainer.epsilon(1e-5)?;
        println!("epoch {epoch}: loss = {loss:.4}   (ε, δ) = ({eps:.3}, 1e-5)");
    }
    let (eval_loss, acc) = trainer.evaluate()?;
    println!("held-out: loss = {eval_loss:.4}, accuracy = {:.1}%", acc * 100.0);
    Ok(())
}
