//! Quickstart — the paper's §2 usage example, two Opacus lines included.
//!
//!     dataset = Dataset(); model = Net(); optimizer = SGD(...)
//!     privacy_engine = PrivacyEngine()                     # line 1
//!     model, optimizer, data_loader = privacy_engine.make_private(...)  # line 2
//!     # Now it's business as usual
//!
//! Here the two lines are the typed builder: `PrivacyEngine::private()`
//! configures, `.build(sys)` wraps — returning a `Private` bundle with
//! the trainer plus optimizer and loader handles (the paper's
//! three-object wrap).
//!
//! Execution is backend-pluggable (`.backend(..)`): `Backend::Auto`
//! (the default) uses AOT XLA artifacts when they exist and otherwise
//! the pure-Rust native per-sample-gradient engine — so this example
//! runs end to end on a machine that never ran `make artifacts`.
//!
//! Run: cargo run --release --example quickstart

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{Backend, PrivacyEngine};

fn main() -> anyhow::Result<()> {
    // dataset + model + optimizer: one loaded system
    // (backend auto-selected: XLA artifacts if present, else native)
    let sys = Opacus::load("artifacts", "mnist")?;
    println!("execution backend: {}", sys.backend_description());

    // the two Opacus lines:
    let mut private = PrivacyEngine::private()
        .backend(Backend::Auto)
        .noise_multiplier(1.1)
        .max_grad_norm(1.0)
        .lr(0.25)
        .logical_batch(64)
        .physical_batch(64)
        .build(sys)?;

    // the bundle mirrors the model/optimizer/loader wrap:
    println!(
        "optimizer: σ = {}, C = {} ({}); loader: {:?}, q = {:.4}, {} steps/epoch",
        private.optimizer.noise_multiplier,
        private.optimizer.max_grad_norm,
        private.optimizer.clipping.as_str(),
        private.loader.sampling,
        private.loader.sample_rate,
        private.loader.steps_per_epoch,
    );

    // now it's business as usual (`Private` derefs to the trainer)
    for epoch in 0..3 {
        let loss = private.train_epoch()?;
        let eps = private.epsilon(1e-5)?;
        println!("epoch {epoch}: loss = {loss:.4}   (ε, δ) = ({eps:.3}, 1e-5)");
    }
    let (eval_loss, acc) = private.evaluate()?;
    println!("held-out: loss = {eval_loss:.4}, accuracy = {:.1}%", acc * 100.0);
    Ok(())
}
