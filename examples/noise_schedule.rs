//! Noise scheduling + heterogeneous accounting (paper §2 "Noise scheduler
//! and variable batch size").
//!
//! Trains with an exponentially *annealing* noise multiplier (γ = 0.9 per
//! epoch) — more noise early, less late — and shows that the accountant
//! composes the per-epoch σ values correctly (each epoch is a separate
//! ledger segment). Also demonstrates the GDP accountant side by side.
//!
//! Run: cargo run --release --example noise_schedule

use opacus_rs::accounting::{Accountant, GdpAccountant, RdpAccountant};
use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::{NoiseScheduler, PrivacyEngine};

fn main() -> anyhow::Result<()> {
    // Backend::Auto: XLA artifacts when present, native engine otherwise
    let sys = Opacus::load_with_data("artifacts", "mnist", 512, 128, 5)?;
    println!("execution backend: {}", sys.backend_name());
    let sample_rate = 64.0 / 512.0;
    let mut trainer = PrivacyEngine::private()
        .noise_multiplier(/* base σ */ 1.4)
        .max_grad_norm(1.0)
        .lr(0.3)
        .logical_batch(64)
        .physical_batch(64)
        .build(sys)?
        .into_trainer();
    trainer.noise_scheduler = NoiseScheduler::Exponential { gamma: 0.9 };

    // shadow ledgers to compare accountants on the same schedule
    let mut shadow_rdp = RdpAccountant::new();
    let mut shadow_gdp = GdpAccountant::new();

    println!("epoch |  σ(t)  | loss    | ε(RDP) | ε(GDP shadow)");
    for epoch in 0..8 {
        let sigma = trainer.current_sigma();
        let loss = trainer.train_epoch()?;
        let steps = trainer.steps_per_epoch() as u64;
        shadow_rdp.record(sigma, sample_rate, steps);
        shadow_gdp.record(sigma, sample_rate, steps);
        println!(
            "{epoch:>5} | {sigma:>6.3} | {loss:<7.4} | {:>6.3} | {:>6.3}",
            trainer.epsilon(1e-5)?,
            shadow_gdp.get_epsilon(1e-5),
        );
        // engine ledger and shadow RDP ledger must agree exactly
        let engine_eps = trainer.epsilon(1e-5)?;
        let shadow_eps = shadow_rdp.get_epsilon(1e-5);
        assert!(
            (engine_eps - shadow_eps).abs() < 1e-9,
            "ledger mismatch: {engine_eps} vs {shadow_eps}"
        );
    }
    println!(
        "\nheterogeneous history segments in the ledger: {}",
        shadow_rdp.history().len()
    );
    println!("(each epoch's annealed σ composes as its own SGM segment)");
    Ok(())
}
