//! Model validation demo (paper Appendix C): the engine rejects
//! DP-incompatible architectures before any training happens, with
//! actionable messages — and custom layer kinds can be registered.
//!
//! Run: cargo run --release --example validate_model

use opacus_rs::coordinator::Opacus;
use opacus_rs::privacy::validator::{validate_model, validate_model_with_custom};
use opacus_rs::privacy::PrivacyEngine;
use opacus_rs::runtime::artifact::ModelMeta;

fn meta(kinds: &[&str]) -> ModelMeta {
    ModelMeta {
        task: "demo".into(),
        num_params: 1000,
        input_shape: vec![32, 32, 3],
        input_dtype: "f32".into(),
        num_classes: 10,
        layer_kinds: kinds.iter().map(|s| s.to_string()).collect(),
        vocab: None,
        init_file: String::new(),
    }
}

fn main() -> anyhow::Result<()> {
    println!("== 1. a DP-compatible model passes ==");
    let good = meta(&["conv2d", "groupnorm", "conv2d", "linear"]);
    let errs = validate_model(&good);
    println!("conv/groupnorm/linear -> {} violations\n", errs.len());

    println!("== 2. BatchNorm is rejected with a fix suggestion ==");
    let bad = meta(&["conv2d", "batchnorm", "linear"]);
    for e in validate_model(&bad) {
        println!("  VIOLATION: {e}");
    }
    println!();

    println!("== 3. unknown layers need a registered per-sample grad rule ==");
    let custom = meta(&["conv2d", "my_custom_attention", "linear"]);
    for e in validate_model(&custom) {
        println!("  VIOLATION: {e}");
    }
    println!("  ...after registering 'my_custom_attention':");
    let errs = validate_model_with_custom(&custom, &["my_custom_attention"]);
    println!("  {} violations\n", errs.len());

    println!("== 4. the builder refuses to wrap an invalid model ==");
    // forge a system whose model metadata carries a batchnorm (works on
    // either backend — Auto falls back to the native engine when no
    // artifacts exist)
    let mut sys = Opacus::load("artifacts", "mnist")?;
    println!("  (execution backend: {})", sys.backend_name());
    sys.model.layer_kinds.push("batchnorm".to_string());
    match PrivacyEngine::private()
        .noise_multiplier(1.1)
        .max_grad_norm(1.0)
        .build(sys)
    {
        Err(e) => println!("  refused as expected:\n  {e}"),
        Ok(_) => anyhow::bail!("validator failed to reject batchnorm!"),
    }
    Ok(())
}
