"""The four Fast-DPSGD benchmark models (L2), on flat parameter vectors.

Models operate on a SINGLE sample; batching happens via vmap in dpsgd.py.
Parameters live in one flat f32 vector so the Rust coordinator can treat
them as an opaque buffer (checkpoints, noise vectors, optimizer state all
become flat-vector ops) — the analogue of Opacus's per-parameter
grad_sample tensors, collapsed into a single address space.

Param counts (paper's Table-1 models):
  * mnist_cnn  — 26,010   (matches the paper exactly)
  * cifar_cnn  — 550,570  (paper: 605,226; same conv-stack family, ~0.6M)
  * imdb_embed — 160,306  (paper: 160,098)
  * imdb_lstm  — 1,081,002 (matches the paper exactly)
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L

VOCAB = 10_000
SEQ_LEN = 64  # paper used full IMDb reviews; scaled for the CPU testbed


class Model:
    """A flat-parameter model: spec + single-sample apply."""

    def __init__(self, name: str, spec: L.Spec, fans: Dict[str, int],
                 apply_fn: Callable, input_shape: Tuple[int, ...],
                 input_dtype: str, num_classes: int,
                 layer_kinds: List[str]):
        self.name = name
        self.spec = spec
        self.fans = fans
        self._apply = apply_fn
        self.input_shape = input_shape
        self.input_dtype = input_dtype  # "f32" | "i32"
        self.num_classes = num_classes
        # layer kinds, for the L3 model validator (Appendix C analogue)
        self.layer_kinds = layer_kinds
        self.offsets = {}
        off = 0
        for pname, shape in spec:
            n = int(np.prod(shape))
            self.offsets[pname] = (off, shape)
            off += n
        self.num_params = off

    # -- flat <-> dict ------------------------------------------------------
    def unpack(self, flat: jnp.ndarray) -> L.Params:
        out = {}
        for pname, (off, shape) in self.offsets.items():
            n = int(np.prod(shape))
            out[pname] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
        return out

    def pack(self, params: L.Params) -> jnp.ndarray:
        return jnp.concatenate(
            [params[pname].reshape(-1) for pname, _ in self.spec])

    def init_flat(self, key) -> jnp.ndarray:
        return self.pack(L.init_params(key, self.spec, self.fans))

    # -- single-sample forward ---------------------------------------------
    def apply(self, flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
        return self._apply(self.unpack(flat), x)

    def loss(self, flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
        return L.softmax_xent(self.apply(flat, x), y)


def _cat(*pieces):
    spec, fans = [], {}
    for s, f in pieces:
        spec += s
        fans.update(f)
    return spec, fans


# ---------------------------------------------------------------------------
# MNIST CNN — 26,010 params (conv16@8x8/s2 → pool → conv32@4x4/s2 → pool →
# dense 32 → dense 10), the TF-Privacy tutorial net used by Fast-DPSGD.
# ---------------------------------------------------------------------------

def mnist_cnn() -> Model:
    spec, fans = _cat(
        L.conv2d_spec("c1", 1, 16, 8),
        L.conv2d_spec("c2", 16, 32, 4),
        L.dense_spec("d1", 512, 32),
        L.dense_spec("d2", 32, 10),
    )

    def apply(p, x):  # x: [28, 28, 1]
        h = L.relu(L.conv2d(p, "c1", x, stride=2, padding="SAME"))   # 14x14x16
        h = L.maxpool2d(h, 2, 1)                                     # 13x13x16
        h = L.relu(L.conv2d(p, "c2", h, stride=2, padding="VALID"))  # 5x5x32
        h = L.maxpool2d(h, 2, 1)                                     # 4x4x32
        h = h.reshape(-1)                                            # 512
        h = L.relu(L.dense(p, "d1", h))
        return L.dense(p, "d2", h)

    return Model("mnist_cnn", spec, fans, apply, (28, 28, 1), "f32", 10,
                 ["conv2d", "conv2d", "linear", "linear"])


# ---------------------------------------------------------------------------
# CIFAR-10 CNN — VGG-ish conv stack (32,32,64,64,128,128) + dense head.
# ---------------------------------------------------------------------------

def cifar_cnn() -> Model:
    spec, fans = _cat(
        L.conv2d_spec("c1", 3, 32, 3),
        L.conv2d_spec("c2", 32, 32, 3),
        L.conv2d_spec("c3", 32, 64, 3),
        L.conv2d_spec("c4", 64, 64, 3),
        L.conv2d_spec("c5", 64, 128, 3),
        L.conv2d_spec("c6", 128, 128, 3),
        L.dense_spec("d1", 2048, 128),
        L.dense_spec("d2", 128, 10),
    )

    def apply(p, x):  # x: [32, 32, 3]
        h = L.relu(L.conv2d(p, "c1", x))
        h = L.relu(L.conv2d(p, "c2", h))
        h = L.avgpool2d(h, 2, 2)                    # 16x16x32
        h = L.relu(L.conv2d(p, "c3", h))
        h = L.relu(L.conv2d(p, "c4", h))
        h = L.avgpool2d(h, 2, 2)                    # 8x8x64
        h = L.relu(L.conv2d(p, "c5", h))
        h = L.relu(L.conv2d(p, "c6", h))
        h = L.avgpool2d(h, 2, 2)                    # 4x4x128
        h = h.reshape(-1)                           # 2048
        h = L.relu(L.dense(p, "d1", h))
        return L.dense(p, "d2", h)

    return Model("cifar_cnn", spec, fans, apply, (32, 32, 3), "f32", 10,
                 ["conv2d"] * 6 + ["linear", "linear"])


# ---------------------------------------------------------------------------
# IMDb embedding net — Embedding(10k,16) → mean-pool → dense 16 → dense 2.
# ---------------------------------------------------------------------------

def imdb_embed() -> Model:
    spec, fans = _cat(
        L.embedding_spec("emb", VOCAB, 16),
        L.dense_spec("d1", 16, 16),
        L.dense_spec("d2", 16, 2),
    )

    def apply(p, x):  # x: [T] int32
        h = L.embedding(p, "emb", x)      # [T, 16]
        h = jnp.mean(h, axis=0)           # [16]
        h = L.relu(L.dense(p, "d1", h))
        return L.dense(p, "d2", h)

    return Model("imdb_embed", spec, fans, apply, (SEQ_LEN,), "i32", 2,
                 ["embedding", "linear", "linear"])


# ---------------------------------------------------------------------------
# IMDb LSTM — Embedding(10k,100) → LSTM(100) → dense 2 (1,081,002 params).
# ---------------------------------------------------------------------------

def imdb_lstm() -> Model:
    spec, fans = _cat(
        L.embedding_spec("emb", VOCAB, 100),
        L.lstm_spec("rnn", 100, 100),
        L.dense_spec("d1", 100, 2),
    )

    def apply(p, x):  # x: [T] int32
        h = L.embedding(p, "emb", x)          # [T, 100]
        hs = L.lstm(p, "rnn", h, 100)         # [T, 100]
        return L.dense(p, "d1", hs[-1])

    return Model("imdb_lstm", spec, fans, apply, (SEQ_LEN,), "i32", 2,
                 ["embedding", "lstm", "linear"])


MODELS: Dict[str, Callable[[], Model]] = {
    "mnist": mnist_cnn,
    "cifar": cifar_cnn,
    "embed": imdb_embed,
    "lstm": imdb_lstm,
}


def get_model(task: str) -> Model:
    return MODELS[task]()
