"""From-scratch JAX layer library (L2).

Every layer is a pair of functions operating on a flat dict of named
parameters:

  * ``<layer>_spec(name, ...) -> [(param_name, shape), ...]``
  * ``<layer>(params, name, x, ...) -> y``

``apply`` functions are written for a SINGLE sample (no batch dimension);
batching is always done with ``jax.vmap`` outside. This is what makes
per-sample gradients (``vmap(grad(...))``) natural, mirroring Opacus's
GradSampleModule which attaches per-sample gradient formulas per layer.

Initialization mirrors PyTorch defaults (Kaiming-uniform fan-in for
linear/conv, U(-1/sqrt(h), 1/sqrt(h)) for recurrent layers, N(0,1) for
embeddings) so learning dynamics are comparable.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Spec = List[Tuple[str, Tuple[int, ...]]]
Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------

def _kaiming_uniform(key, shape, fan_in):
    bound = math.sqrt(1.0 / max(1, fan_in))
    return jax.random.uniform(key, shape, jnp.float32, -bound, bound)


def init_params(key, spec: Spec, fan_ins: Dict[str, int]) -> Params:
    """Initialize every parameter in ``spec``.

    ``fan_ins`` maps parameter name -> fan-in used for the uniform bound;
    names ending in ``.emb`` are drawn from N(0, 1) like torch.nn.Embedding.
    """
    params = {}
    keys = jax.random.split(key, max(2, len(spec)))
    for (name, shape), k in zip(spec, keys):
        if name.endswith(".emb"):
            params[name] = jax.random.normal(k, shape, jnp.float32)
        else:
            params[name] = _kaiming_uniform(k, shape, fan_ins[name])
    return params


# ---------------------------------------------------------------------------
# dense / linear
# ---------------------------------------------------------------------------

def dense_spec(name: str, d_in: int, d_out: int) -> Tuple[Spec, Dict[str, int]]:
    spec = [(f"{name}.w", (d_in, d_out)), (f"{name}.b", (d_out,))]
    fans = {f"{name}.w": d_in, f"{name}.b": d_in}
    return spec, fans


def dense(params: Params, name: str, x: jnp.ndarray) -> jnp.ndarray:
    return x @ params[f"{name}.w"] + params[f"{name}.b"]


# ---------------------------------------------------------------------------
# conv2d (single sample, HWC)
# ---------------------------------------------------------------------------

def conv2d_spec(name: str, c_in: int, c_out: int, k: int) -> Tuple[Spec, Dict[str, int]]:
    spec = [(f"{name}.w", (k, k, c_in, c_out)), (f"{name}.b", (c_out,))]
    fan = k * k * c_in
    return spec, {f"{name}.w": fan, f"{name}.b": fan}


def conv2d(params: Params, name: str, x: jnp.ndarray, stride: int = 1,
           padding: str = "SAME") -> jnp.ndarray:
    """x: [H, W, C_in] -> [H', W', C_out]."""
    w = params[f"{name}.w"]
    y = lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]
    return y + params[f"{name}.b"]


def maxpool2d(x: jnp.ndarray, k: int, stride: int) -> jnp.ndarray:
    """x: [H, W, C]."""
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (k, k, 1), (stride, stride, 1), "VALID"
    )


def avgpool2d(x: jnp.ndarray, k: int, stride: int) -> jnp.ndarray:
    s = lax.reduce_window(x, 0.0, lax.add, (k, k, 1), (stride, stride, 1), "VALID")
    return s / float(k * k)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------

def embedding_spec(name: str, vocab: int, dim: int) -> Tuple[Spec, Dict[str, int]]:
    return [(f"{name}.emb", (vocab, dim))], {f"{name}.emb": vocab}


def embedding(params: Params, name: str, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: [T] int32 -> [T, dim]."""
    return params[f"{name}.emb"][tokens]


# ---------------------------------------------------------------------------
# normalization layers (all DP-compatible: per-sample statistics only).
# BatchNorm is deliberately NOT implemented: it mixes samples across the
# batch and is rejected by the validator (paper §2 "Model validation").
# ---------------------------------------------------------------------------

def layernorm_spec(name: str, dim: int) -> Tuple[Spec, Dict[str, int]]:
    spec = [(f"{name}.g", (dim,)), (f"{name}.b", (dim,))]
    return spec, {f"{name}.g": 1, f"{name}.b": 1}


def layernorm(params: Params, name: str, x: jnp.ndarray, eps: float = 1e-5):
    """Normalizes over the last axis of a single sample."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xn = (x - mu) / jnp.sqrt(var + eps)
    return xn * params[f"{name}.g"] + params[f"{name}.b"]


def groupnorm_spec(name: str, channels: int) -> Tuple[Spec, Dict[str, int]]:
    spec = [(f"{name}.g", (channels,)), (f"{name}.b", (channels,))]
    return spec, {f"{name}.g": 1, f"{name}.b": 1}


def groupnorm(params: Params, name: str, x: jnp.ndarray, groups: int,
              eps: float = 1e-5):
    """x: [H, W, C]; normalizes within channel groups of one sample."""
    h, w, c = x.shape
    xg = x.reshape(h, w, groups, c // groups)
    mu = jnp.mean(xg, axis=(0, 1, 3), keepdims=True)
    var = jnp.var(xg, axis=(0, 1, 3), keepdims=True)
    xn = ((xg - mu) / jnp.sqrt(var + eps)).reshape(h, w, c)
    return xn * params[f"{name}.g"] + params[f"{name}.b"]


def instancenorm_spec(name: str, channels: int) -> Tuple[Spec, Dict[str, int]]:
    spec = [(f"{name}.g", (channels,)), (f"{name}.b", (channels,))]
    return spec, {f"{name}.g": 1, f"{name}.b": 1}


def instancenorm(params: Params, name: str, x: jnp.ndarray, eps: float = 1e-5):
    """x: [H, W, C]; per-channel statistics of one sample.

    track_running_stats is not representable here by construction — the
    functional form keeps no cross-batch state, which is exactly the
    configuration Opacus's validator demands.
    """
    mu = jnp.mean(x, axis=(0, 1), keepdims=True)
    var = jnp.var(x, axis=(0, 1), keepdims=True)
    xn = (x - mu) / jnp.sqrt(var + eps)
    return xn * params[f"{name}.g"] + params[f"{name}.b"]


# ---------------------------------------------------------------------------
# multi-head attention (single sample: x [T, D])
# ---------------------------------------------------------------------------

def mha_spec(name: str, dim: int) -> Tuple[Spec, Dict[str, int]]:
    spec, fans = [], {}
    for p in ("q", "k", "v", "o"):
        s, f = dense_spec(f"{name}.{p}", dim, dim)
        spec += s
        fans.update(f)
    return spec, fans


def mha(params: Params, name: str, x: jnp.ndarray, heads: int) -> jnp.ndarray:
    t, d = x.shape
    hd = d // heads
    q = dense(params, f"{name}.q", x).reshape(t, heads, hd)
    k = dense(params, f"{name}.k", x).reshape(t, heads, hd)
    v = dense(params, f"{name}.v", x).reshape(t, heads, hd)
    att = jnp.einsum("qhd,khd->hqk", q, k) / math.sqrt(hd)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("hqk,khd->qhd", att, v).reshape(t, d)
    return dense(params, f"{name}.o", out)


# ---------------------------------------------------------------------------
# recurrent layers (single sample: x [T, D] -> hidden states [T, H])
#
# Two implementations are provided, mirroring the paper's Fig. 5 comparison
# of torch.nn modules vs Opacus's custom modules:
#   * fused=True  — one [D+H, n_gates*H] matmul per step (our optimized
#     "custom module"), the hot-path variant;
#   * fused=False — per-gate matmuls (the naive reference), used as the
#     "unoptimized module" series in the Fig. 5 reproduction.
# Both use torch-style double biases so parameter counts match torch.nn.
# ---------------------------------------------------------------------------

def _rnn_gate_spec(name: str, d: int, h: int, gates: int):
    spec = [
        (f"{name}.wi", (d, gates * h)),
        (f"{name}.wh", (h, gates * h)),
        (f"{name}.bi", (gates * h,)),
        (f"{name}.bh", (gates * h,)),
    ]
    fans = {f"{name}.wi": h, f"{name}.wh": h, f"{name}.bi": h, f"{name}.bh": h}
    return spec, fans


def rnn_spec(name: str, d: int, h: int):
    return _rnn_gate_spec(name, d, h, 1)


def gru_spec(name: str, d: int, h: int):
    return _rnn_gate_spec(name, d, h, 3)


def lstm_spec(name: str, d: int, h: int):
    return _rnn_gate_spec(name, d, h, 4)


def _gates(params, name, x_t, h_t, n, fused):
    """Returns the [n*H] pre-activation gate vector for one time step."""
    if fused:
        return (
            x_t @ params[f"{name}.wi"]
            + h_t @ params[f"{name}.wh"]
            + params[f"{name}.bi"]
            + params[f"{name}.bh"]
        )
    # naive: slice the fused weights and do per-gate matmuls (more kernels,
    # more memory traffic — the "unoptimized custom module" baseline).
    hsz = params[f"{name}.wh"].shape[0]
    outs = []
    for g in range(n):
        wi = lax.dynamic_slice_in_dim(params[f"{name}.wi"], g * hsz, hsz, 1)
        wh = lax.dynamic_slice_in_dim(params[f"{name}.wh"], g * hsz, hsz, 1)
        bi = lax.dynamic_slice_in_dim(params[f"{name}.bi"], g * hsz, hsz, 0)
        bh = lax.dynamic_slice_in_dim(params[f"{name}.bh"], g * hsz, hsz, 0)
        outs.append(x_t @ wi + h_t @ wh + bi + bh)
    return jnp.concatenate(outs, axis=-1)


def rnn(params: Params, name: str, x: jnp.ndarray, h: int, fused: bool = True):
    """Elman RNN with tanh. x: [T, D] -> [T, H]."""

    def step(h_t, x_t):
        h_new = jnp.tanh(_gates(params, name, x_t, h_t, 1, fused))
        return h_new, h_new

    h0 = jnp.zeros((h,), x.dtype)
    _, hs = lax.scan(step, h0, x)
    return hs


def gru(params: Params, name: str, x: jnp.ndarray, h: int, fused: bool = True):
    """GRU (torch gate order r, z, n). x: [T, D] -> [T, H]."""
    hsz = h

    def step(h_t, x_t):
        if fused:
            gi = x_t @ params[f"{name}.wi"] + params[f"{name}.bi"]
            gh = h_t @ params[f"{name}.wh"] + params[f"{name}.bh"]
        else:
            # naive variant: per-gate matmuls (more kernels, more traffic)
            gi_parts, gh_parts = [], []
            for g in range(3):
                wi = lax.dynamic_slice_in_dim(params[f"{name}.wi"], g * hsz, hsz, 1)
                wh = lax.dynamic_slice_in_dim(params[f"{name}.wh"], g * hsz, hsz, 1)
                bi = lax.dynamic_slice_in_dim(params[f"{name}.bi"], g * hsz, hsz, 0)
                bh = lax.dynamic_slice_in_dim(params[f"{name}.bh"], g * hsz, hsz, 0)
                gi_parts.append(x_t @ wi + bi)
                gh_parts.append(h_t @ wh + bh)
            gi = jnp.concatenate(gi_parts)
            gh = jnp.concatenate(gh_parts)
        ir, iz, in_ = jnp.split(gi, 3)
        hr, hz, hn = jnp.split(gh, 3)
        r = jax.nn.sigmoid(ir + hr)
        z = jax.nn.sigmoid(iz + hz)
        n = jnp.tanh(in_ + r * hn)
        h_new = (1.0 - z) * n + z * h_t
        return h_new, h_new

    h0 = jnp.zeros((hsz,), x.dtype)
    _, hs = lax.scan(step, h0, x)
    return hs


def lstm(params: Params, name: str, x: jnp.ndarray, h: int, fused: bool = True):
    """LSTM (torch gate order i, f, g, o). x: [T, D] -> [T, H]."""

    def step(carry, x_t):
        h_t, c_t = carry
        z = _gates(params, name, x_t, h_t, 4, fused)
        i, f, g, o = jnp.split(z, 4)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
        g = jnp.tanh(g)
        c_new = f * c_t + i * g
        h_new = o * jnp.tanh(c_new)
        return (h_new, c_new), h_new

    h0 = jnp.zeros((h,), x.dtype)
    (_, _), hs = lax.scan(step, (h0, h0), x)
    return hs


# ---------------------------------------------------------------------------
# activations / losses
# ---------------------------------------------------------------------------

def relu(x):
    return jnp.maximum(x, 0.0)


def softmax_xent(logits: jnp.ndarray, label: jnp.ndarray) -> jnp.ndarray:
    """Cross-entropy for a single sample: logits [K], label scalar int."""
    logz = jax.scipy.special.logsumexp(logits)
    return logz - logits[label]
