"""L1 Pallas kernels — the DP-SGD hot spot.

Three kernels implement the per-sample-gradient machinery the paper builds
its speed claims on:

  * ``per_sample_sq_norms``  — tiled reduction g[B,N] -> ||g_b||² [B]
  * ``clip_accumulate``      — tiled contraction coef[B] @ g[B,N] -> [N]
  * ``linear_gsm``           — batched outer product dy[B,r] ⊗ x[B,d]
                               (Appendix B's einsum as a kernel)

Hardware adaptation (paper: CUDA einsum on A100 → here: TPU-shaped Pallas):
the GPU implementation leans on cuBLAS batched GEMM; on TPU the same
insight — express per-sample work as one large contraction — maps to MXU
tiles. ``clip_accumulate`` streams parameter tiles HBM→VMEM via BlockSpec
with the per-sample coefficient vector resident, accumulating into the
output block across the batch grid axis (the reduction axis is innermost,
so each output tile stays in VMEM for the whole reduction). All kernels
run under ``interpret=True`` — CPU PJRT cannot execute Mosaic custom
calls — so block shapes are chosen for VMEM budgets, not CPU wallclock
(see DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block-size policy (perf-iterated — full log in EXPERIMENTS.md §Perf L1):
#   it.1  BlockSpec grid, (8, 2048) VMEM micro-tiles:   14.9 s  @ B=512/P=26k
#   it.2  BlockSpec grid, (512, 8192) tiles:             0.27 s (grid steps
#         copy the full operand under interpret=True)
#   it.3  JAX-level chunking, 16 MiB tiles:              0.06 s isolated but
#         3x step cost in-graph (column slices of the computed [B, P]
#         gradient tensor are real copies on CPU)
#   it.4  JAX-level chunking, 1 GiB budget (usually one  ~jnp parity
#         whole-array tile; chunking only bounds host RAM)
# The real-TPU schedule — (8, 2048)-tile double-buffered BlockSpec grid,
# reduction axis innermost — is preserved compile-ready in the `*_grid`
# variants below; the interpret path optimizes structure for the CPU
# emulation it actually runs on.
_TILE_F32_BUDGET = 256 * 1024 * 1024
_BN_MIN, _BN_MAX = 2048, 1 << 20


def _auto_blocks(b: int, n: int) -> tuple:
    """(bb, bn): full-batch rows, VMEM-budgeted parameter tile."""
    bb = max(1, b)
    bn = _TILE_F32_BUDGET // bb
    bn = max(_BN_MIN, min(_BN_MAX, bn))
    bn = max(128, (bn // 128) * 128)  # lane-aligned
    return bb, min(bn, max(128, ((n + 127) // 128) * 128))


def _pad2(g: jnp.ndarray, bb: int, bn: int) -> jnp.ndarray:
    b, n = g.shape
    pb = (-b) % bb
    pn = (-n) % bn
    if pb or pn:
        g = jnp.pad(g, ((0, pb), (0, pn)))
    return g


# ---------------------------------------------------------------------------
# per-sample squared norms
# ---------------------------------------------------------------------------

def _sq_norm_kernel(g_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = g_ref[...]
    o_ref[...] += jnp.sum(blk * blk, axis=1)


def per_sample_sq_norms_grid(g: jnp.ndarray, bb: int = 8, bn: int = 2048):
    """BlockSpec-grid variant — the schedule a real TPU build uses
    (HBM→VMEM streaming with the reduction axis innermost). Kept
    compile-ready and correctness-tested at small sizes; NOT used on the
    interpret hot path (grid steps copy full operands — §Perf L1)."""
    b, _ = g.shape
    gp = _pad2(g.astype(jnp.float32), bb, bn)
    pb, pn = gp.shape
    out = pl.pallas_call(
        _sq_norm_kernel,
        grid=(pb // bb, pn // bn),  # N (reduction) axis innermost
        in_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((pb,), jnp.float32),
        interpret=True,
    )(gp)
    return out[:b]


def _sq_norm_tile(g: jnp.ndarray) -> jnp.ndarray:
    """One [B, bn] tile -> [B] partial squared norms (single-cell call)."""
    b, _ = g.shape
    return pl.pallas_call(
        _sq_norm_tile_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(g)


def _sq_norm_tile_kernel(g_ref, o_ref):
    blk = g_ref[...]
    o_ref[...] = jnp.sum(blk * blk, axis=1)


def per_sample_sq_norms(g: jnp.ndarray, bb: int = 0, bn: int = 0):
    """g: [B, N] -> [B] squared L2 norms (Pallas, interpret mode).

    Tiling happens at the JAX level (slices + one single-tile pallas call
    per chunk, partial sums added outside): the interpreter's grid loop
    carries the FULL operand through every grid step (measured ~0.2 s per
    step at B=512 — EXPERIMENTS.md §Perf L1), whereas XLA slices are
    zero-copy. On real TPU the same tile schedule is expressed with the
    BlockSpec grid (`_grid_*` variants below, compile-only).
    """
    b, n = g.shape
    if bb == 0 or bn == 0:
        bb, bn = _auto_blocks(b, n)
    g = g.astype(jnp.float32)
    total = jnp.zeros((b,), jnp.float32)
    for off in range(0, n, bn):
        total = total + _sq_norm_tile(g[:, off:min(off + bn, n)])
    return total


# ---------------------------------------------------------------------------
# clip-scale-accumulate: out = coef @ g
# ---------------------------------------------------------------------------

def _clip_accum_kernel(c_ref, g_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # [BB] · [BB, BN] -> [BN]: an MXU-friendly (1,B)x(B,N) contraction.
    o_ref[...] += jnp.dot(c_ref[...], g_ref[...],
                          preferred_element_type=jnp.float32)


def clip_accumulate_grid(g: jnp.ndarray, coef: jnp.ndarray,
                         bb: int = 8, bn: int = 2048):
    """BlockSpec-grid variant of `clip_accumulate` (TPU schedule; see
    `per_sample_sq_norms_grid`)."""
    b, n = g.shape
    gp = _pad2(g.astype(jnp.float32), bb, bn)
    pb, pn = gp.shape
    cp = jnp.pad(coef.astype(jnp.float32), (0, pb - b))
    out = pl.pallas_call(
        _clip_accum_kernel,
        grid=(pn // bn, pb // bb),  # B (reduction) axis innermost
        in_specs=[
            pl.BlockSpec((bb,), lambda i, j: (j,)),
            pl.BlockSpec((bb, bn), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((pn,), jnp.float32),
        interpret=True,
    )(cp, gp)
    return out[:n]


def _clip_accum_tile(coef: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """One [B, bn] tile -> [bn] contraction coef @ g (single-cell call)."""
    _, bn = g.shape
    return pl.pallas_call(
        _clip_accum_tile_kernel,
        out_shape=jax.ShapeDtypeStruct((bn,), jnp.float32),
        interpret=True,
    )(coef, g)


def _clip_accum_tile_kernel(c_ref, g_ref, o_ref):
    # [B] · [B, BN] -> [BN]: an MXU-friendly (1,B)x(B,N) contraction.
    o_ref[...] = jnp.dot(c_ref[...], g_ref[...],
                         preferred_element_type=jnp.float32)


def clip_accumulate(g: jnp.ndarray, coef: jnp.ndarray,
                    bb: int = 0, bn: int = 0):
    """g: [B, N], coef: [B] -> [N] = Σ_b coef[b]·g[b,:] (Pallas).

    JAX-level tiling along the parameter axis (see `per_sample_sq_norms`
    for the rationale); each chunk is one single-cell pallas call whose
    tile fits the host tile budget.
    """
    b, n = g.shape
    if bb == 0 or bn == 0:
        bb, bn = _auto_blocks(b, n)
    g = g.astype(jnp.float32)
    coef = coef.astype(jnp.float32)
    if n <= bn:
        return _clip_accum_tile(coef, g)
    pieces = [
        _clip_accum_tile(coef, g[:, off:min(off + bn, n)])
        for off in range(0, n, bn)
    ]
    return jnp.concatenate(pieces)


# ---------------------------------------------------------------------------
# per-sample linear-layer gradient (batched outer product)
# ---------------------------------------------------------------------------

def _linear_gsm_kernel(dy_ref, x_ref, o_ref):
    o_ref[...] = dy_ref[...][:, :, None] * x_ref[...][:, None, :]


def linear_gsm(dy: jnp.ndarray, x: jnp.ndarray, bb: int = 8):
    """dy: [B, r], x: [B, d] -> [B, r, d] per-sample weight gradients."""
    b, r = dy.shape
    _, d = x.shape
    pb = (-b) % bb
    if pb:
        dy = jnp.pad(dy, ((0, pb), (0, 0)))
        x = jnp.pad(x, ((0, pb), (0, 0)))
    out = pl.pallas_call(
        _linear_gsm_kernel,
        grid=((b + pb) // bb,),
        in_specs=[
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, r, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b + pb, r, d), jnp.float32),
        interpret=True,
    )(dy.astype(jnp.float32), x.astype(jnp.float32))
    return out[:b]


# ---------------------------------------------------------------------------
# fused convenience: norms -> coefs -> accumulate (one call from L2)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def clip_and_aggregate(g: jnp.ndarray, mask: jnp.ndarray, clip: jnp.ndarray):
    """Full clip path over flattened per-sample grads g [B, P].

    Returns (gsum [P], sq_norms [B]). This is the composition the dp_step
    lowers into its HLO: both Pallas kernels plus the tiny coef formula.
    """
    sq = per_sample_sq_norms(g)
    norms = jnp.sqrt(sq + 1e-12)
    coef = mask * jnp.minimum(1.0, clip / norms)
    return clip_accumulate(g, coef), sq
