"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

These are the semantics the kernels must match bit-for-bit (up to float
accumulation order). They are also used directly by the ``jaxstyle`` step
variant — the paper's "JAX (DP)" comparison row — so the ablation
(Pallas-structured vs XLA-fused clipping) shares one definition of truth.
"""

import jax.numpy as jnp


def per_sample_sq_norms(g: jnp.ndarray) -> jnp.ndarray:
    """g: [B, N] per-sample flattened gradients -> [B] squared L2 norms."""
    return jnp.sum(g * g, axis=1)


def clip_accumulate(g: jnp.ndarray, coef: jnp.ndarray) -> jnp.ndarray:
    """g: [B, N], coef: [B] -> [N] = sum_b coef[b] * g[b, :].

    With coef[b] = mask[b] * min(1, C / ||g_b||) this is the DP-SGD
    clip-and-aggregate step (Abadi et al. '16), i.e. the einsum of the
    paper's Appendix B with the per-sample clip factor folded in.
    """
    return coef @ g


def clip_coefs(sq_norms: jnp.ndarray, clip: jnp.ndarray,
               mask: jnp.ndarray) -> jnp.ndarray:
    """Per-sample clip factors: mask * min(1, C / ||g||)."""
    norms = jnp.sqrt(sq_norms + 1e-12)
    return mask * jnp.minimum(1.0, clip / norms)


def linear_gsm(dy: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Per-sample weight gradients of a linear layer.

    dy: [B, r] highway gradients, x: [B, d] activations
    -> [B, r, d] with out[b, i, j] = dy[b, i] * x[b, j]
    (the paper's torch.einsum("n...i,n...j->nij", B, A)).
    """
    return jnp.einsum("ni,nj->nij", dy, x)
