"""Per-layer microbenchmark modules (L2) — the paper's §3.2 workloads.

For every layer Opacus supports we build two (for recurrent layers,
three) step graphs over a batch of inputs:

  * ``nodp``  — one forward + one backward pass, gradients averaged over
                the batch (the ``torch.nn`` row of Fig. 2/5);
  * ``dp``    — one forward + one *per-sample* backward pass, then the
                L1 clip-and-aggregate kernels (the ``GSM(module)`` row);
  * ``naive`` — recurrent layers only: the unfused per-gate variant
                without DP (the "Opacus custom module" row of Fig. 5).
                Their ``dp`` variant also uses the unfused cell, matching
                the paper where GradSampleModule wraps the custom module.

The per-layer loss is ½‖f(x)‖² per sample, which exercises exactly one
fwd + one bwd through the layer, the quantity Table 2/3 measures.

Signatures:
  nodp(params[P], x[B,...]) -> (grad[P], loss[])
  dp  (params[P], x[B,...], mask[B], clip[]) -> (gsum[P], loss[], snorm_mean[])
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .kernels import dp_kernels


class LayerBench:
    """A single-layer workload: flat params + single-sample apply."""

    def __init__(self, name: str, spec, fans, apply_fn,
                 input_shape: Tuple[int, ...], input_dtype: str = "f32"):
        self.name = name
        self.spec = spec
        self.fans = fans
        self._apply = apply_fn
        self.input_shape = input_shape
        self.input_dtype = input_dtype
        self.offsets = {}
        off = 0
        for pname, shape in spec:
            self.offsets[pname] = (off, shape)
            off += int(np.prod(shape))
        self.num_params = off

    def unpack(self, flat):
        out = {}
        for pname, (off, shape) in self.offsets.items():
            n = int(np.prod(shape))
            out[pname] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
        return out

    def init_flat(self, key):
        p = L.init_params(key, self.spec, self.fans)
        return jnp.concatenate([p[n].reshape(-1) for n, _ in self.spec])

    def apply(self, flat, x):
        return self._apply(self.unpack(flat), x)


# ---------------------------------------------------------------------------
# layer zoo — shapes follow the spirit of opacus/benchmarks/config.json
# ---------------------------------------------------------------------------

def linear_bench() -> LayerBench:
    spec, fans = L.dense_spec("l", 512, 512)
    return LayerBench("linear", spec, fans,
                      lambda p, x: L.dense(p, "l", x), (512,))


def conv_bench() -> LayerBench:
    spec, fans = L.conv2d_spec("c", 3, 32, 3)
    return LayerBench("conv", spec, fans,
                      lambda p, x: L.conv2d(p, "c", x), (32, 32, 3))


def layernorm_bench() -> LayerBench:
    spec, fans = L.layernorm_spec("n", 256)
    return LayerBench("layernorm", spec, fans,
                      lambda p, x: L.layernorm(p, "n", x), (256,))


def groupnorm_bench() -> LayerBench:
    spec, fans = L.groupnorm_spec("n", 32)
    return LayerBench("groupnorm", spec, fans,
                      lambda p, x: L.groupnorm(p, "n", x, groups=8),
                      (16, 16, 32))


def instancenorm_bench() -> LayerBench:
    spec, fans = L.instancenorm_spec("n", 32)
    return LayerBench("instancenorm", spec, fans,
                      lambda p, x: L.instancenorm(p, "n", x), (16, 16, 32))


def embedding_bench(vocab: int = 1000, dim: int = 16,
                    seq: int = 32) -> LayerBench:
    spec, fans = L.embedding_spec("e", vocab, dim)
    name = "embedding" if vocab == 1000 else f"embedding_v{vocab}"
    return LayerBench(name, spec, fans,
                      lambda p, x: L.embedding(p, "e", x), (seq,), "i32")


def mha_bench() -> LayerBench:
    spec, fans = L.mha_spec("a", 128)
    return LayerBench("mha", spec, fans,
                      lambda p, x: L.mha(p, "a", x, heads=8), (64, 128))


def _rnn_family(kind: str, fused: bool) -> LayerBench:
    d, h, t = 128, 128, 32
    spec_fn = {"rnn": L.rnn_spec, "gru": L.gru_spec, "lstm": L.lstm_spec}[kind]
    apply_raw = {"rnn": L.rnn, "gru": L.gru, "lstm": L.lstm}[kind]
    spec, fans = spec_fn("r", d, h)
    return LayerBench(kind, spec, fans,
                      lambda p, x: apply_raw(p, "r", x, h, fused=fused),
                      (t, d))


LAYERS: Dict[str, Callable[[], LayerBench]] = {
    "linear": linear_bench,
    "conv": conv_bench,
    "layernorm": layernorm_bench,
    "groupnorm": groupnorm_bench,
    "instancenorm": instancenorm_bench,
    "embedding": embedding_bench,
    "mha": mha_bench,
    "rnn": lambda: _rnn_family("rnn", True),
    "gru": lambda: _rnn_family("gru", True),
    "lstm": lambda: _rnn_family("lstm", True),
    "rnn_naive": lambda: _rnn_family("rnn", False),
    "gru_naive": lambda: _rnn_family("gru", False),
    "lstm_naive": lambda: _rnn_family("lstm", False),
}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def _sample_loss(bench: LayerBench, params, xi):
    out = bench.apply(params, xi)
    return 0.5 * jnp.sum(out * out)


def make_layer_nodp(bench: LayerBench) -> Callable:
    def step(params, x):
        def mean_loss(p):
            losses = jax.vmap(lambda xi: _sample_loss(bench, p, xi))(x)
            return jnp.mean(losses)

        loss, g = jax.value_and_grad(mean_loss)(params)
        return g, loss

    return step


def make_layer_dp(bench: LayerBench) -> Callable:
    def step(params, x, mask, clip):
        def one(xi, mi):
            loss, g = jax.value_and_grad(
                lambda p: _sample_loss(bench, p, xi) * mi)(params)
            return g, loss

        grads, losses = jax.vmap(one)(x, mask)
        gsum, sq = dp_kernels.clip_and_aggregate(grads, mask, clip)
        nm = jnp.maximum(jnp.sum(mask), 1.0)
        snorm_mean = jnp.sum(jnp.sqrt(sq + 1e-12) * mask) / nm
        return gsum, jnp.sum(losses) / nm, snorm_mean

    return step


def layer_example_args(bench: LayerBench, variant: str, batch: int):
    xdt = jnp.float32 if bench.input_dtype == "f32" else jnp.int32
    p = jax.ShapeDtypeStruct((bench.num_params,), jnp.float32)
    x = jax.ShapeDtypeStruct((batch,) + bench.input_shape, xdt)
    if variant in ("nodp", "naive"):
        return (p, x)
    m = jax.ShapeDtypeStruct((batch,), jnp.float32)
    s = jax.ShapeDtypeStruct((), jnp.float32)
    return (p, x, m, s)


def build_layer_step(bench: LayerBench, variant: str) -> Callable:
    if variant in ("nodp", "naive"):
        return make_layer_nodp(bench)
    if variant == "dp":
        return make_layer_dp(bench)
    raise ValueError(f"unknown layer variant {variant}")
