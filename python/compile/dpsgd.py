"""DP-SGD step builders (L2): the compute graphs the Rust coordinator runs.

Every builder returns a jittable function over concrete shapes; aot.py
lowers them to HLO text once at build time. Hyperparameters (lr, clip
norm C, noise multiplier σ, denominator) are *runtime scalar inputs*, so
the L3 noise/batch schedulers never trigger re-lowering.

Step signatures (all f32 unless noted):

  dp_step(params[P], x[B,...], y[B]i32, mask[B], noise[P],
          lr[], clip[], sigma[], denom[])
      -> (params'[P], loss[], snorm_mean[])
  jaxstyle_step — same signature; pure-jnp clip path (ablation row,
      the paper's "JAX (DP)" analogue)
  nodp_step(params, x, y, mask, lr, denom) -> (params', loss)
  grad_accum(params, x, y, mask, clip) -> (gsum[P], loss_sum[], snorm_sum[])
  apply_update(params, gsum, noise, lr, clip, sigma, denom) -> params'
  eval_step(params, x, y, mask) -> (loss_sum[], correct[])

Per-sample gradients come from ``vmap(grad(per_sample_loss))`` over the
flat parameter vector — one batched backward pass, the vectorized
computation the paper contrasts with micro-batching (Appendix A/B). The
clip-and-aggregate stage routes through the L1 Pallas kernels
(``kernels.dp_kernels``), so they lower into the same HLO module.

DP-SGD semantics (Abadi et al. '16, as implemented by Opacus):
  update = lr * (Σ_b clip_C(g_b) + σ·C·ξ) / denom,   ξ ~ N(0, I)
where denom is the *expected* (logical) batch size under Poisson sampling.
Masked (padding) rows contribute exactly zero: their per-sample loss is
multiplied by mask[b], so g_b = 0 and the clip coefficient is masked too.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import dp_kernels, ref
from .models import Model


def _per_sample_grads(model: Model, params, x, y, mask):
    """One vectorized backward pass -> (grads [B,P], losses [B])."""

    def sample_loss(p, xi, yi, mi):
        return model.loss(p, xi, yi) * mi

    def one(xi, yi, mi):
        loss, g = jax.value_and_grad(sample_loss)(params, xi, yi, mi)
        return g, loss

    grads, losses = jax.vmap(one)(x, y, mask)
    return grads, losses


def _noisy_update(params, gsum, noise, lr, clip, sigma, denom):
    return params - lr * (gsum + sigma * clip * noise) / denom


def make_dp_step(model: Model, use_pallas: bool = True) -> Callable:
    """The fused DP-SGD step (per-sample grads → clip → noise → update)."""

    def dp_step(params, x, y, mask, noise, lr, clip, sigma, denom):
        grads, losses = _per_sample_grads(model, params, x, y, mask)
        if use_pallas:
            gsum, sq = dp_kernels.clip_and_aggregate(grads, mask, clip)
        else:
            sq = ref.per_sample_sq_norms(grads)
            coef = ref.clip_coefs(sq, clip, mask)
            gsum = ref.clip_accumulate(grads, coef)
        new_params = _noisy_update(params, gsum, noise, lr, clip, sigma, denom)
        nmask = jnp.sum(mask)
        loss = jnp.sum(losses) / jnp.maximum(nmask, 1.0)
        snorm_mean = jnp.sum(jnp.sqrt(sq + 1e-12) * mask) / jnp.maximum(nmask, 1.0)
        return new_params, loss, snorm_mean

    return dp_step


def make_nodp_step(model: Model) -> Callable:
    """Plain SGD over the masked mean loss — the 'PyTorch without DP' row."""

    def mean_loss(params, x, y, mask):
        losses = jax.vmap(lambda xi, yi: model.loss(params, xi, yi))(x, y)
        return jnp.sum(losses * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    def nodp_step(params, x, y, mask, lr, denom):
        loss, g = jax.value_and_grad(mean_loss)(params, x, y, mask)
        return params - lr * g * (jnp.sum(mask) / denom), loss

    return nodp_step


def make_grad_accum(model: Model, use_pallas: bool = True) -> Callable:
    """Clipped-gradient accumulation only — the virtual-step half."""

    def grad_accum(params, x, y, mask, clip):
        grads, losses = _per_sample_grads(model, params, x, y, mask)
        if use_pallas:
            gsum, sq = dp_kernels.clip_and_aggregate(grads, mask, clip)
        else:
            sq = ref.per_sample_sq_norms(grads)
            gsum = ref.clip_accumulate(grads, ref.clip_coefs(sq, clip, mask))
        snorm_sum = jnp.sum(jnp.sqrt(sq + 1e-12) * mask)
        return gsum, jnp.sum(losses), snorm_sum

    return grad_accum


def make_apply_update(model: Model) -> Callable:
    """Noise + parameter update from an accumulated clipped-gradient sum."""

    def apply_update(params, gsum, noise, lr, clip, sigma, denom):
        return _noisy_update(params, gsum, noise, lr, clip, sigma, denom)

    return apply_update


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, x, y, mask):
        def one(xi, yi):
            logits = model.apply(params, xi)
            return model.loss(params, xi, yi), jnp.argmax(logits).astype(jnp.int32)

        losses, preds = jax.vmap(one)(x, y)
        correct = jnp.sum((preds == y).astype(jnp.float32) * mask)
        return jnp.sum(losses * mask), correct

    return eval_step


# ---------------------------------------------------------------------------
# example-input builders (for jax.jit(...).lower(...))
# ---------------------------------------------------------------------------

def _xy_spec(model: Model, batch: int):
    xdt = jnp.float32 if model.input_dtype == "f32" else jnp.int32
    x = jax.ShapeDtypeStruct((batch,) + model.input_shape, xdt)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return x, y


def example_args(model: Model, kind: str, batch: int):
    """Abstract input signature for each step kind."""
    f32 = jnp.float32
    p = jax.ShapeDtypeStruct((model.num_params,), f32)
    x, y = _xy_spec(model, batch)
    m = jax.ShapeDtypeStruct((batch,), f32)
    s = jax.ShapeDtypeStruct((), f32)
    if kind in ("dp", "jaxstyle", "microbatch"):
        return (p, x, y, m, p, s, s, s, s)
    if kind == "nodp":
        return (p, x, y, m, s, s)
    if kind == "accum":
        return (p, x, y, m, s)
    if kind == "apply":
        return (p, p, p, s, s, s, s)
    if kind == "eval":
        return (p, x, y, m)
    raise ValueError(f"unknown step kind {kind}")


def build_step(model: Model, kind: str) -> Callable:
    if kind in ("dp", "microbatch"):
        return make_dp_step(model, use_pallas=True)
    if kind == "jaxstyle":
        return make_dp_step(model, use_pallas=False)
    if kind == "nodp":
        return make_nodp_step(model)
    if kind == "accum":
        return make_grad_accum(model, use_pallas=True)
    if kind == "apply":
        return make_apply_update(model)
    if kind == "eval":
        return make_eval_step(model)
    raise ValueError(f"unknown step kind {kind}")
