"""AOT compiler (build-time entry point): lower every step graph to HLO text.

This is the only place Python touches the pipeline; after ``make
artifacts`` the Rust coordinator is self-contained. For each artifact we

  1. build the step function (dpsgd.py / microbench.py),
  2. ``jax.jit(fn).lower(*abstract_args)``,
  3. convert the StableHLO module to an XlaComputation and dump **HLO
     text** (not ``.serialize()`` — xla_extension 0.5.1 rejects jax≥0.5's
     64-bit-id protos; the text parser reassigns ids),
  4. record the typed input/output signature in ``manifest.json``.

We also emit initial flat parameters (``<task>_init.npy``) and golden
input/output vectors for the Rust integration tests.

Usage:  python -m compile.aot [--out-dir ../artifacts] [--only REGEX]
                              [--skip-existing] [--list]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import time

import numpy as np

import jax
from jax._src.lib import xla_client as xc

from . import dpsgd, microbench, models

# ---------------------------------------------------------------------------
# build plan
# ---------------------------------------------------------------------------

E2E_BATCHES = {
    "mnist": [16, 32, 64, 128, 256, 512],
    "embed": [16, 32, 64, 128, 256, 512],
    "cifar": [16, 64, 256],
    "lstm": [16, 64, 256],
}
JAXSTYLE_BATCHES = {"mnist": [16, 64, 256], "embed": [16, 64, 256]}
CANON_BATCH = 64  # accum / apply / eval batch

LAYER_BATCHES = {
    "linear": [16, 64, 256, 512],
    "embedding": [16, 64, 128, 256, 512],
    "conv": [16, 64, 256],
    "layernorm": [16, 64, 256],
    "groupnorm": [16, 64, 256],
    "instancenorm": [16, 64, 256],
    "mha": [16, 64, 256],
    "rnn": [16, 64, 256],
    "gru": [16, 64, 256],
    "lstm": [16, 64, 256],
}
FIG3_VOCABS = [100, 10_000]       # 1000 is the default embedding bench
FIG3_BATCHES = [16, 128, 512]

STEP_INPUT_NAMES = {
    "dp": ["params", "x", "y", "mask", "noise", "lr", "clip", "sigma", "denom"],
    "jaxstyle": ["params", "x", "y", "mask", "noise", "lr", "clip", "sigma", "denom"],
    "microbatch": ["params", "x", "y", "mask", "noise", "lr", "clip", "sigma", "denom"],
    "nodp": ["params", "x", "y", "mask", "lr", "denom"],
    "accum": ["params", "x", "y", "mask", "clip"],
    "apply": ["params", "gsum", "noise", "lr", "clip", "sigma", "denom"],
    "eval": ["params", "x", "y", "mask"],
}
STEP_OUTPUT_NAMES = {
    "dp": ["params", "loss", "snorm_mean"],
    "jaxstyle": ["params", "loss", "snorm_mean"],
    "microbatch": ["params", "loss", "snorm_mean"],
    "nodp": ["params", "loss"],
    "accum": ["gsum", "loss_sum", "snorm_sum"],
    "apply": ["params"],
    "eval": ["loss_sum", "correct"],
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sig(avals, names):
    out = []
    for name, a in zip(names, avals):
        dt = {"float32": "f32", "int32": "i32"}[str(a.dtype)]
        out.append({"name": name, "dtype": dt, "shape": [int(d) for d in a.shape]})
    return out


def _out_sig(lowered, names):
    avals = jax.tree_util.tree_leaves(lowered.out_info)
    return _sig(avals, names)


class Entry:
    def __init__(self, name, build_fn, meta):
        self.name = name
        self.build = build_fn     # () -> (fn, example_args)
        self.meta = meta          # manifest fields


def plan() -> list:
    entries = []

    # ---- end-to-end training steps -------------------------------------
    for task in ("mnist", "cifar", "embed", "lstm"):
        model = models.get_model(task)

        def mk(task=task, kind=None, batch=None):
            def build():
                m = models.get_model(task)
                fn = dpsgd.build_step(m, kind)
                return fn, dpsgd.example_args(m, kind, batch)
            return build

        combos = []
        for b in E2E_BATCHES[task]:
            combos += [("dp", b), ("nodp", b)]
        for b in JAXSTYLE_BATCHES.get(task, []):
            combos.append(("jaxstyle", b))
        combos.append(("microbatch", 1))
        combos += [("accum", CANON_BATCH), ("apply", CANON_BATCH),
                   ("eval", CANON_BATCH)]

        for kind, b in combos:
            name = f"{task}_{kind}_b{b}"
            entries.append(Entry(
                name, mk(task=task, kind=kind, batch=b),
                {"kind": "train", "task": task, "variant": kind, "batch": b,
                 "num_params": model.num_params}))

    # ---- per-layer microbenchmarks --------------------------------------
    def layer_entries(bench_fn, lname, variants, batches):
        bench0 = bench_fn()
        for variant in variants:
            for b in batches:
                name = f"layer_{lname}_{variant}_b{b}"

                def build(bench_fn=bench_fn, variant=variant, b=b):
                    bench = bench_fn()
                    fn = microbench.build_layer_step(bench, variant)
                    return fn, microbench.layer_example_args(bench, variant, b)

                in_bytes = int(np.prod(bench0.input_shape)) * 4
                entries.append(Entry(
                    name, build,
                    {"kind": "layer", "layer": lname, "variant": variant,
                     "batch": b, "num_params": bench0.num_params,
                     "input_shape": list(bench0.input_shape),
                     "input_dtype": bench0.input_dtype,
                     "sample_input_bytes": in_bytes}))

    for lname in ("linear", "conv", "layernorm", "groupnorm",
                  "instancenorm", "embedding", "mha"):
        layer_entries(microbench.LAYERS[lname], lname, ("nodp", "dp"),
                      LAYER_BATCHES[lname])
    for lname in ("rnn", "gru", "lstm"):
        # fused cell without DP = the torch.nn row of Fig. 5
        layer_entries(microbench.LAYERS[lname], lname, ("nodp",),
                      LAYER_BATCHES[lname])
        # naive (custom-module) cell, without and with DP = Fig. 5 rows
        layer_entries(microbench.LAYERS[f"{lname}_naive"], f"{lname}_naive",
                      ("naive", "dp"), LAYER_BATCHES[lname])

    # ---- Fig. 3 embedding vocab sweep ------------------------------------
    for vocab in FIG3_VOCABS:
        layer_entries(lambda vocab=vocab: microbench.embedding_bench(vocab),
                      f"embedding_v{vocab}", ("nodp", "dp"), FIG3_BATCHES)

    return entries


# ---------------------------------------------------------------------------
# goldens — concrete i/o vectors for the Rust integration tests
# ---------------------------------------------------------------------------

def _rand_inputs(model, batch, rng):
    if model.input_dtype == "f32":
        x = rng.standard_normal((batch,) + model.input_shape).astype(np.float32)
    else:
        x = rng.integers(0, models.VOCAB,
                         (batch,) + model.input_shape).astype(np.int32)
    y = rng.integers(0, model.num_classes, (batch,)).astype(np.int32)
    return x, y


def emit_goldens(out_dir: str, task: str) -> list:
    model = models.get_model(task)
    rng = np.random.default_rng(123)
    params = np.asarray(model.init_flat(jax.random.PRNGKey(7)))
    np.save(os.path.join(out_dir, f"{task}_init.npy"), params)

    goldens = []
    # dp step golden (b16)
    b = 16
    x, y = _rand_inputs(model, b, rng)
    mask = np.ones((b,), np.float32)
    noise = rng.standard_normal((model.num_params,)).astype(np.float32)
    lr, clip, sigma, denom = np.float32(0.05), np.float32(1.0), \
        np.float32(1.1), np.float32(b)
    fn = jax.jit(dpsgd.build_step(model, "dp"))
    p2, loss, snorm = fn(params, x, y, mask, noise, lr, clip, sigma, denom)
    files = {}
    for nm, arr in [("params", params), ("x", x), ("y", y), ("mask", mask),
                    ("noise", noise),
                    ("out_params", np.asarray(p2)),
                    ("out_loss", np.asarray(loss).reshape(1)),
                    ("out_snorm", np.asarray(snorm).reshape(1))]:
        f = f"golden_{task}_dp_{nm}.npy"
        np.save(os.path.join(out_dir, f), np.asarray(arr))
        files[nm] = f
    goldens.append({"task": task, "step": "dp", "batch": b,
                    "scalars": {"lr": 0.05, "clip": 1.0, "sigma": 1.1,
                                "denom": float(b)},
                    "files": files, "rtol": 2e-4, "atol": 1e-5})

    # eval golden (canonical batch)
    b = CANON_BATCH
    x, y = _rand_inputs(model, b, rng)
    mask = np.ones((b,), np.float32)
    fn = jax.jit(dpsgd.build_step(model, "eval"))
    loss_sum, correct = fn(params, x, y, mask)
    files = {}
    for nm, arr in [("x", x), ("y", y), ("mask", mask),
                    ("out_loss_sum", np.asarray(loss_sum).reshape(1)),
                    ("out_correct", np.asarray(correct).reshape(1))]:
        f = f"golden_{task}_eval_{nm}.npy"
        np.save(os.path.join(out_dir, f), np.asarray(arr))
        files[nm] = f
    goldens.append({"task": task, "step": "eval", "batch": b,
                    "files": files, "rtol": 1e-4, "atol": 1e-4})
    return goldens


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="regex over artifact names")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-goldens", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    entries = plan()
    if args.only:
        rx = re.compile(args.only)
        entries = [e for e in entries if rx.search(e.name)]
    if args.list:
        for e in entries:
            print(e.name)
        print(f"{len(entries)} artifacts")
        return

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"version": 1, "models": {}, "artifacts": [], "goldens": []}
    for task in ("mnist", "cifar", "embed", "lstm"):
        m = models.get_model(task)
        manifest["models"][task] = {
            "num_params": m.num_params,
            "input_shape": list(m.input_shape),
            "input_dtype": m.input_dtype,
            "num_classes": m.num_classes,
            "layer_kinds": m.layer_kinds,
            "vocab": models.VOCAB if m.input_dtype == "i32" else None,
            "init_file": f"{task}_init.npy",
        }

    t_total = time.time()
    for i, e in enumerate(entries):
        hlo_path = os.path.join(out_dir, f"{e.name}.hlo.txt")
        t0 = time.time()
        fn, ex_args = e.build()
        lowered = jax.jit(fn).lower(*ex_args)
        if e.meta["kind"] == "train":
            in_names = STEP_INPUT_NAMES[e.meta["variant"]]
            out_names = STEP_OUTPUT_NAMES[e.meta["variant"]]
        elif e.meta["variant"] in ("nodp", "naive"):
            in_names, out_names = ["params", "x"], ["grad", "loss"]
        else:
            in_names = ["params", "x", "mask", "clip"]
            out_names = ["gsum", "loss", "snorm_mean"]
        record = dict(e.meta)
        record["name"] = e.name
        record["file"] = f"{e.name}.hlo.txt"
        record["inputs"] = _sig(ex_args, in_names)
        record["outputs"] = _out_sig(lowered, out_names)
        manifest["artifacts"].append(record)

        if args.skip_existing and os.path.exists(hlo_path):
            print(f"[{i+1}/{len(entries)}] {e.name}: exists, kept", flush=True)
            continue
        text = to_hlo_text(lowered)
        with open(hlo_path, "w") as f:
            f.write(text)
        print(f"[{i+1}/{len(entries)}] {e.name}: {len(text)/1024:.0f} KiB "
              f"in {time.time()-t0:.1f}s", flush=True)

    if not args.no_goldens and not args.only:
        for task in ("mnist", "cifar", "embed", "lstm"):
            manifest["goldens"] += emit_goldens(out_dir, task)
            print(f"goldens: {task}", flush=True)

    if args.only is None:
        # a filtered build must not clobber the full manifest
        with open(os.path.join(out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts in "
          f"{time.time()-t_total:.0f}s -> {out_dir}")


if __name__ == "__main__":
    main()
