"""Per-layer microbench graphs: DP and non-DP steps agree on semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import microbench as mb

ALL_LAYERS = ["linear", "conv", "layernorm", "groupnorm", "instancenorm",
              "embedding", "mha", "rnn", "gru", "lstm"]


def _x(bench, b, seed=0):
    if bench.input_dtype == "f32":
        return jax.random.normal(jax.random.PRNGKey(seed),
                                 (b,) + bench.input_shape, jnp.float32)
    vocab = bench.spec[0][1][0]
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (b,) + bench.input_shape, 0, vocab, jnp.int32)


@pytest.mark.parametrize("lname", ALL_LAYERS)
def test_nodp_grad_matches_autodiff(lname):
    bench = mb.LAYERS[lname]()
    p = bench.init_flat(jax.random.PRNGKey(1))
    x = _x(bench, 4)
    g, loss = mb.make_layer_nodp(bench)(p, x)
    assert g.shape == (bench.num_params,)
    assert np.isfinite(float(loss))

    def mean_loss(pp):
        return jnp.mean(jax.vmap(
            lambda xi: 0.5 * jnp.sum(bench.apply(pp, xi) ** 2))(x))

    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(jax.grad(mean_loss)(p)),
                               rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("lname", ["linear", "conv", "embedding", "mha", "lstm"])
def test_dp_step_clips(lname):
    """DP per-layer step: aggregated gradient obeys the clip bound."""
    bench = mb.LAYERS[lname]()
    p = bench.init_flat(jax.random.PRNGKey(2))
    b = 4
    x = _x(bench, b, seed=3)
    clip = 0.01  # aggressively small: every sample will be clipped
    gsum, loss, snorm = mb.make_layer_dp(bench)(
        p, x, jnp.ones((b,)), jnp.float32(clip))
    assert float(jnp.linalg.norm(gsum)) <= b * clip * (1 + 1e-3)
    assert float(snorm) > 0.0


@pytest.mark.parametrize("lname", ["linear", "layernorm"])
def test_dp_unclipped_equals_sum_of_grads(lname):
    bench = mb.LAYERS[lname]()
    p = bench.init_flat(jax.random.PRNGKey(4))
    b = 3
    x = _x(bench, b, seed=5)
    gsum, _, _ = mb.make_layer_dp(bench)(p, x, jnp.ones((b,)),
                                         jnp.float32(1e9))
    g_mean, _ = mb.make_layer_nodp(bench)(p, x)
    np.testing.assert_allclose(np.asarray(gsum), np.asarray(g_mean) * b,
                               rtol=2e-4, atol=1e-5)


def test_naive_rnn_same_function_as_fused():
    fused = mb.LAYERS["lstm"]()
    naive = mb.LAYERS["lstm_naive"]()
    p = fused.init_flat(jax.random.PRNGKey(6))
    x = _x(fused, 2, seed=7)
    gf, lf = mb.make_layer_nodp(fused)(p, x)
    gn, ln = mb.make_layer_nodp(naive)(p, x)
    np.testing.assert_allclose(float(lf), float(ln), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gn),
                               rtol=2e-4, atol=1e-5)


def test_embedding_vocab_scaling():
    small = mb.embedding_bench(100)
    big = mb.embedding_bench(10_000)
    assert big.num_params == 100 * small.num_params
    assert small.name == "embedding_v100"
    assert mb.embedding_bench(1000).name == "embedding"


@pytest.mark.parametrize("lname", ALL_LAYERS)
def test_layer_steps_lower(lname):
    """Every microbench graph must be AOT-lowerable (the build contract)."""
    bench = mb.LAYERS[lname]()
    for variant in ("nodp", "dp"):
        fn = mb.build_layer_step(bench, variant)
        jax.jit(fn).lower(*mb.layer_example_args(bench, variant, 2))
