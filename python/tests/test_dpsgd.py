"""DP-SGD step semantics: vectorized steps vs the micro-batch oracle.

The key equivalence the paper is built on (Appendix A vs Appendix B):
the vectorized per-sample-gradient step must produce exactly what the
naive per-sample loop produces.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import dpsgd, models


@pytest.fixture(scope="module")
def mnist():
    m = models.get_model("mnist")
    p = m.init_flat(jax.random.PRNGKey(0))
    return m, p


def _batch(m, b, seed=1):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    if m.input_dtype == "f32":
        x = jax.random.normal(kx, (b,) + m.input_shape, jnp.float32)
    else:
        x = jax.random.randint(kx, (b,) + m.input_shape, 0, models.VOCAB,
                               jnp.int32)
    y = jax.random.randint(ky, (b,), 0, m.num_classes, jnp.int32)
    return x, y


def _microbatch_oracle(m, p, x, y, clip):
    """Appendix-A algorithm: loop, clip, sum — the ground truth."""
    gsum = np.zeros(m.num_params, np.float32)
    for i in range(x.shape[0]):
        g = np.asarray(jax.grad(lambda pp: m.loss(pp, x[i], y[i]))(p))
        norm = np.linalg.norm(g)
        gsum += g * min(1.0, clip / max(norm, 1e-12))
    return gsum


S = jnp.float32


class TestDpStepVsOracle:
    @pytest.mark.parametrize("clip", [0.1, 1.0, 100.0])
    def test_matches_microbatch(self, mnist, clip):
        m, p = mnist
        b = 6
        x, y = _batch(m, b)
        mask = jnp.ones((b,))
        noise = jnp.zeros_like(p)
        step = dpsgd.make_dp_step(m)
        p2, _, _ = step(p, x, y, mask, noise, S(0.1), S(clip), S(0.0), S(b))
        gsum = _microbatch_oracle(m, p, x, y, clip)
        want = np.asarray(p) - 0.1 * gsum / b
        np.testing.assert_allclose(np.asarray(p2), want, rtol=3e-4, atol=1e-6)

    def test_pallas_and_jaxstyle_agree(self, mnist):
        m, p = mnist
        b = 8
        x, y = _batch(m, b, seed=2)
        mask = jnp.ones((b,))
        noise = jax.random.normal(jax.random.PRNGKey(3), p.shape)
        args = (p, x, y, mask, noise, S(0.05), S(1.0), S(1.1), S(b))
        pa, la, sa = dpsgd.make_dp_step(m, use_pallas=True)(*args)
        pj, lj, sj = dpsgd.make_dp_step(m, use_pallas=False)(*args)
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pj),
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(float(la), float(lj), rtol=1e-5)
        np.testing.assert_allclose(float(sa), float(sj), rtol=1e-4)

    def test_noise_applied_with_correct_scale(self, mnist):
        m, p = mnist
        b = 4
        x, y = _batch(m, b, seed=4)
        mask = jnp.zeros((b,))  # no data contribution: pure noise update
        noise = jax.random.normal(jax.random.PRNGKey(5), p.shape)
        lr, clip, sigma = 0.1, 2.0, 1.5
        step = dpsgd.make_dp_step(m)
        p2, _, _ = step(p, x, y, mask, noise, S(lr), S(clip), S(sigma), S(b))
        want = np.asarray(p) - lr * sigma * clip * np.asarray(noise) / b
        np.testing.assert_allclose(np.asarray(p2), want, rtol=1e-5, atol=1e-7)

    def test_masked_rows_are_invisible(self, mnist):
        """Padding rows (Poisson loader) must not affect the update at all."""
        m, p = mnist
        x, y = _batch(m, 4, seed=6)
        noise = jnp.zeros_like(p)
        step = dpsgd.make_dp_step(m)
        args_full = (p, x, y, jnp.array([1., 1., 0., 0.]), noise,
                     S(0.1), S(1.0), S(0.0), S(2.0))
        p_masked, _, _ = step(*args_full)
        x2, y2 = x[:2], y[:2]
        p_sub, _, _ = step(p, x2, y2, jnp.ones((2,)), noise,
                           S(0.1), S(1.0), S(0.0), S(2.0))
        np.testing.assert_allclose(np.asarray(p_masked), np.asarray(p_sub),
                                   rtol=1e-5, atol=1e-7)


class TestVirtualSteps:
    def test_accum_plus_apply_equals_fused(self, mnist):
        """grad_accum ∘ apply_update == dp_step (the virtual-step split)."""
        m, p = mnist
        b = 8
        x, y = _batch(m, b, seed=7)
        mask = jnp.ones((b,))
        noise = jax.random.normal(jax.random.PRNGKey(8), p.shape)
        lr, clip, sigma, denom = 0.05, 1.0, 1.1, float(b)

        gsum, _, _ = dpsgd.make_grad_accum(m)(p, x, y, mask, S(clip))
        p_split = dpsgd.make_apply_update(m)(
            p, gsum, noise, S(lr), S(clip), S(sigma), S(denom))
        p_fused, _, _ = dpsgd.make_dp_step(m)(
            p, x, y, mask, noise, S(lr), S(clip), S(sigma), S(denom))
        np.testing.assert_allclose(np.asarray(p_split), np.asarray(p_fused),
                                   rtol=1e-5, atol=1e-7)

    def test_two_physical_batches_equal_one_logical(self, mnist):
        """Accumulating 2×4 then applying == one fused step over 8."""
        m, p = mnist
        x, y = _batch(m, 8, seed=9)
        mask4 = jnp.ones((4,))
        clip, lr, denom = 1.0, 0.1, 8.0
        accum = dpsgd.make_grad_accum(m)
        g1, _, _ = accum(p, x[:4], y[:4], mask4, S(clip))
        g2, _, _ = accum(p, x[4:], y[4:], mask4, S(clip))
        p_virtual = dpsgd.make_apply_update(m)(
            p, g1 + g2, jnp.zeros_like(p), S(lr), S(clip), S(0.0), S(denom))
        p_native, _, _ = dpsgd.make_dp_step(m)(
            p, x, y, jnp.ones((8,)), jnp.zeros_like(p),
            S(lr), S(clip), S(0.0), S(denom))
        np.testing.assert_allclose(np.asarray(p_virtual), np.asarray(p_native),
                                   rtol=1e-4, atol=1e-6)


class TestNoDpStep:
    def test_plain_sgd(self, mnist):
        m, p = mnist
        b = 4
        x, y = _batch(m, b, seed=10)
        mask = jnp.ones((b,))
        p2, loss = dpsgd.make_nodp_step(m)(p, x, y, mask, S(0.1), S(b))

        def mean_loss(pp):
            return jnp.mean(jax.vmap(lambda xi, yi: m.loss(pp, xi, yi))(x, y))

        g = jax.grad(mean_loss)(p)
        np.testing.assert_allclose(np.asarray(p2), np.asarray(p - 0.1 * g),
                                   rtol=1e-4, atol=1e-7)
        np.testing.assert_allclose(float(loss), float(mean_loss(p)), rtol=1e-5)


class TestEvalStep:
    def test_counts_correct(self, mnist):
        m, p = mnist
        b = 16
        x, y = _batch(m, b, seed=11)
        mask = jnp.ones((b,))
        loss_sum, correct = dpsgd.make_eval_step(m)(p, x, y, mask)
        logits = jax.vmap(lambda xi: m.apply(p, xi))(x)
        preds = jnp.argmax(logits, axis=1)
        assert float(correct) == float(jnp.sum(preds == y))
        assert float(loss_sum) > 0.0

    def test_mask_respected(self, mnist):
        m, p = mnist
        x, y = _batch(m, 4, seed=12)
        _, c_all = dpsgd.make_eval_step(m)(p, x, y, jnp.ones((4,)))
        _, c_none = dpsgd.make_eval_step(m)(p, x, y, jnp.zeros((4,)))
        assert float(c_none) == 0.0
        assert float(c_all) >= float(c_none)


class TestTrainingSignal:
    def test_loss_decreases_without_noise(self, mnist):
        """A few DP steps (σ=0) on a fixed batch must reduce the loss —
        the end-to-end learning sanity check at the Python level."""
        m, p = mnist
        b = 16
        x, y = _batch(m, b, seed=13)
        mask = jnp.ones((b,))
        step = jax.jit(dpsgd.make_dp_step(m))
        noise = jnp.zeros_like(p)
        first = None
        for i in range(10):
            p, loss, _ = step(p, x, y, mask, noise, S(0.5), S(1.0), S(0.0), S(b))
            if first is None:
                first = float(loss)
        assert float(loss) < first
