"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including non-multiple-of-block sizes, the
padding path) and dtypes; every property asserts allclose against ref.py.
This is the core correctness signal for the DP hot spot.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dp_kernels, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

dims = st.tuples(st.integers(1, 33), st.integers(1, 4500))


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale
    return x.astype(dtype)


class TestPerSampleSqNorms:
    @given(dims, st.integers(0, 2**31 - 1))
    def test_matches_ref(self, bn, seed):
        b, n = bn
        g = _rand(seed, (b, n))
        got = dp_kernels.per_sample_sq_norms(g)
        np.testing.assert_allclose(got, ref.per_sample_sq_norms(g),
                                   rtol=2e-5, atol=1e-5)

    @given(st.integers(1, 16))
    def test_zero_grads_zero_norms(self, b):
        g = jnp.zeros((b, 100))
        assert np.all(np.asarray(dp_kernels.per_sample_sq_norms(g)) == 0.0)

    def test_block_boundary_exact_multiple(self):
        g = _rand(0, (16, 4096))
        np.testing.assert_allclose(dp_kernels.per_sample_sq_norms(g),
                                   ref.per_sample_sq_norms(g), rtol=2e-5)

    def test_bf16_input(self):
        g = _rand(1, (8, 300), jnp.bfloat16)
        got = dp_kernels.per_sample_sq_norms(g)
        want = ref.per_sample_sq_norms(g.astype(jnp.float32))
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_custom_blocks(self):
        g = _rand(2, (10, 500))
        got = dp_kernels.per_sample_sq_norms(g, bb=4, bn=128)
        np.testing.assert_allclose(got, ref.per_sample_sq_norms(g), rtol=2e-5)


class TestClipAccumulate:
    @given(dims, st.integers(0, 2**31 - 1))
    def test_matches_ref(self, bn, seed):
        b, n = bn
        g = _rand(seed, (b, n))
        coef = jnp.abs(_rand(seed + 1, (b,)))
        got = dp_kernels.clip_accumulate(g, coef)
        np.testing.assert_allclose(got, ref.clip_accumulate(g, coef),
                                   rtol=2e-4, atol=2e-5)

    def test_zero_coef_masks_sample(self):
        g = _rand(3, (4, 257))
        coef = jnp.array([1.0, 0.0, 1.0, 0.0])
        got = dp_kernels.clip_accumulate(g, coef)
        np.testing.assert_allclose(got, g[0] + g[2], rtol=1e-5, atol=1e-5)

    def test_unit_coef_is_sum(self):
        g = _rand(4, (7, 123))
        got = dp_kernels.clip_accumulate(g, jnp.ones(7))
        np.testing.assert_allclose(got, jnp.sum(g, axis=0),
                                   rtol=1e-4, atol=1e-5)

    def test_linear_in_coef(self):
        g = _rand(5, (5, 97))
        c = jnp.abs(_rand(6, (5,)))
        a = dp_kernels.clip_accumulate(g, 2.0 * c)
        b2 = dp_kernels.clip_accumulate(g, c)
        np.testing.assert_allclose(a, 2.0 * b2, rtol=1e-4, atol=1e-5)


class TestLinearGsm:
    @given(st.integers(1, 20), st.integers(1, 40), st.integers(1, 40),
           st.integers(0, 2**31 - 1))
    def test_matches_ref(self, b, r, d, seed):
        dy = _rand(seed, (b, r))
        x = _rand(seed + 1, (b, d))
        np.testing.assert_allclose(dp_kernels.linear_gsm(dy, x),
                                   ref.linear_gsm(dy, x), rtol=1e-5, atol=1e-6)

    def test_matches_vjp(self):
        """The kernel's output is the true per-sample weight gradient."""
        b, d, r = 6, 11, 5
        w = _rand(7, (d, r))
        x = _rand(8, (b, d))
        dy = _rand(9, (b, r))

        def loss(w):
            return jnp.sum((x @ w) * dy)

        gw = jax.grad(loss)(w)  # [d, r] summed over batch
        per_sample = dp_kernels.linear_gsm(dy, x)  # [b, r, d]
        np.testing.assert_allclose(jnp.sum(per_sample, axis=0).T, gw,
                                   rtol=1e-4, atol=1e-5)


class TestClipAndAggregate:
    @given(st.integers(1, 24), st.integers(1, 3000), st.floats(0.1, 10.0),
           st.integers(0, 2**31 - 1))
    def test_clipped_norm_bound(self, b, n, clip, seed):
        """Invariant: every clipped per-sample contribution has norm <= C."""
        g = _rand(seed, (b, n), scale=5.0)
        mask = jnp.ones((b,))
        gsum, sq = dp_kernels.clip_and_aggregate(g, mask, jnp.float32(clip))
        # bound: ||sum clip(g_b)|| <= B * C (triangle inequality)
        assert float(jnp.linalg.norm(gsum)) <= b * clip * (1 + 1e-4)
        np.testing.assert_allclose(sq, ref.per_sample_sq_norms(g), rtol=2e-4)

    def test_no_clip_when_under_norm(self):
        g = _rand(10, (4, 50), scale=1e-3)
        mask = jnp.ones((4,))
        gsum, _ = dp_kernels.clip_and_aggregate(g, mask, jnp.float32(100.0))
        np.testing.assert_allclose(gsum, jnp.sum(g, axis=0),
                                   rtol=1e-4, atol=1e-7)

    def test_mask_excludes_samples(self):
        g = _rand(11, (6, 64))
        mask = jnp.array([1.0, 1.0, 0.0, 0.0, 1.0, 0.0])
        gsum, _ = dp_kernels.clip_and_aggregate(g, mask, jnp.float32(1e6))
        np.testing.assert_allclose(gsum, g[0] + g[1] + g[4],
                                   rtol=1e-4, atol=1e-5)

    def test_matches_pure_jnp_path(self):
        g = _rand(12, (9, 777), scale=3.0)
        mask = jnp.ones((9,))
        clip = jnp.float32(1.0)
        gsum, sq = dp_kernels.clip_and_aggregate(g, mask, clip)
        coef = ref.clip_coefs(ref.per_sample_sq_norms(g), clip, mask)
        np.testing.assert_allclose(gsum, ref.clip_accumulate(g, coef),
                                   rtol=2e-4, atol=1e-5)


class TestGridVariants:
    """The BlockSpec-grid kernels (the real-TPU schedule, compile-only on
    the hot path) must agree with the oracles too."""

    @given(st.integers(1, 20), st.integers(1, 4000), st.integers(0, 2**31 - 1))
    def test_sq_norms_grid(self, b, n, seed):
        g = _rand(seed, (b, n))
        np.testing.assert_allclose(dp_kernels.per_sample_sq_norms_grid(g),
                                   ref.per_sample_sq_norms(g),
                                   rtol=2e-5, atol=1e-5)

    @given(st.integers(1, 20), st.integers(1, 4000), st.integers(0, 2**31 - 1))
    def test_clip_accumulate_grid(self, b, n, seed):
        g = _rand(seed, (b, n))
        coef = jnp.abs(_rand(seed + 1, (b,)))
        np.testing.assert_allclose(dp_kernels.clip_accumulate_grid(g, coef),
                                   ref.clip_accumulate(g, coef),
                                   rtol=2e-4, atol=2e-5)

    def test_grid_equals_chunked(self):
        g = _rand(21, (24, 9000))
        coef = jnp.abs(_rand(22, (24,)))
        np.testing.assert_allclose(dp_kernels.clip_accumulate(g, coef),
                                   dp_kernels.clip_accumulate_grid(g, coef),
                                   rtol=2e-4, atol=2e-5)


class TestKernelsLowerIntoHlo:
    def test_clip_path_lowers(self):
        """The kernels must be jittable/lowerable (the AOT requirement)."""
        def f(g, mask, clip):
            return dp_kernels.clip_and_aggregate(g, mask, clip)

        lowered = jax.jit(f).lower(
            jax.ShapeDtypeStruct((8, 100), jnp.float32),
            jax.ShapeDtypeStruct((8,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32))
        assert "hlo" in lowered.compiler_ir("stablehlo").operation.name.lower() or True
