"""Build-plan and manifest sanity (runs against a generated artifacts/ dir
when present; plan-level checks always run)."""

import json
import os

import pytest

from compile import aot, models

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestPlan:
    def test_plan_names_unique(self):
        names = [e.name for e in aot.plan()]
        assert len(names) == len(set(names))

    def test_plan_covers_every_table1_cell(self):
        names = {e.name for e in aot.plan()}
        for task, batches in aot.E2E_BATCHES.items():
            for b in batches:
                assert f"{task}_dp_b{b}" in names
                assert f"{task}_nodp_b{b}" in names
            assert f"{task}_microbatch_b1" in names

    def test_plan_covers_fig2_layers(self):
        names = {e.name for e in aot.plan()}
        for lname in ("linear", "conv", "layernorm", "groupnorm",
                      "instancenorm", "embedding", "mha"):
            for b in aot.LAYER_BATCHES[lname]:
                assert f"layer_{lname}_dp_b{b}" in names
                assert f"layer_{lname}_nodp_b{b}" in names

    def test_plan_covers_fig5_custom_modules(self):
        names = {e.name for e in aot.plan()}
        for lname in ("rnn", "gru", "lstm"):
            assert f"layer_{lname}_nodp_b64" in names        # torch.nn row
            assert f"layer_{lname}_naive_naive_b64" in names  # custom row
            assert f"layer_{lname}_naive_dp_b64" in names     # GSM row

    def test_plan_covers_fig3_sweep(self):
        names = {e.name for e in aot.plan()}
        for v in aot.FIG3_VOCABS:
            for b in aot.FIG3_BATCHES:
                assert f"layer_embedding_v{v}_dp_b{b}" in names

    def test_virtual_step_artifacts_present(self):
        names = {e.name for e in aot.plan()}
        for task in ("mnist", "cifar", "embed", "lstm"):
            for kind in ("accum", "apply", "eval"):
                assert f"{task}_{kind}_b{aot.CANON_BATCH}" in names


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)")


@needs_artifacts
class TestGeneratedManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_every_artifact_file_exists(self, manifest):
        for a in manifest["artifacts"]:
            assert os.path.exists(os.path.join(ART, a["file"])), a["name"]

    def test_model_metadata(self, manifest):
        for task, meta in manifest["models"].items():
            m = models.get_model(task)
            assert meta["num_params"] == m.num_params
            assert tuple(meta["input_shape"]) == m.input_shape
            assert os.path.exists(os.path.join(ART, meta["init_file"]))

    def test_dp_signature(self, manifest):
        a = next(x for x in manifest["artifacts"]
                 if x["name"] == "mnist_dp_b16")
        in_names = [i["name"] for i in a["inputs"]]
        assert in_names == ["params", "x", "y", "mask", "noise",
                            "lr", "clip", "sigma", "denom"]
        assert a["inputs"][0]["shape"] == [26010]
        assert a["inputs"][1]["shape"] == [16, 28, 28, 1]
        assert a["inputs"][2]["dtype"] == "i32"
        out_names = [o["name"] for o in a["outputs"]]
        assert out_names == ["params", "loss", "snorm_mean"]

    def test_goldens_exist(self, manifest):
        assert len(manifest["goldens"]) == 8  # 4 tasks × (dp + eval)
        for g in manifest["goldens"]:
            for f in g["files"].values():
                assert os.path.exists(os.path.join(ART, f))

    def test_hlo_text_parseable_header(self, manifest):
        a = manifest["artifacts"][0]
        with open(os.path.join(ART, a["file"])) as f:
            head = f.read(200)
        assert "HloModule" in head
