"""L2 models: param counts, pack/unpack round-trip, forward shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models

TASKS = ["mnist", "cifar", "embed", "lstm"]

PAPER_COUNTS = {
    "mnist": 26_010,      # exact match to the paper
    "lstm": 1_081_002,    # exact match to the paper
}


@pytest.fixture(scope="module", params=TASKS)
def task(request):
    return request.param


def _example_input(m, key=0):
    if m.input_dtype == "f32":
        return jax.random.normal(jax.random.PRNGKey(key),
                                 m.input_shape, jnp.float32)
    return jax.random.randint(jax.random.PRNGKey(key), m.input_shape,
                              0, models.VOCAB, jnp.int32)


class TestParamCounts:
    @pytest.mark.parametrize("t,count", PAPER_COUNTS.items())
    def test_exact_paper_counts(self, t, count):
        assert models.get_model(t).num_params == count

    def test_cifar_magnitude(self):
        n = models.get_model("cifar").num_params
        assert 500_000 < n < 700_000  # paper: 605,226; same family

    def test_embed_magnitude(self):
        n = models.get_model("embed").num_params
        assert 159_000 < n < 162_000  # paper: 160,098


class TestPackUnpack:
    def test_roundtrip(self, task):
        m = models.get_model(task)
        flat = m.init_flat(jax.random.PRNGKey(1))
        assert flat.shape == (m.num_params,)
        repacked = m.pack(m.unpack(flat))
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(repacked))

    def test_offsets_cover_vector(self, task):
        m = models.get_model(task)
        total = sum(int(np.prod(s)) for _, (_, s) in m.offsets.items())
        assert total == m.num_params


class TestForward:
    def test_logit_shape(self, task):
        m = models.get_model(task)
        flat = m.init_flat(jax.random.PRNGKey(2))
        out = m.apply(flat, _example_input(m))
        assert out.shape == (m.num_classes,)
        assert np.all(np.isfinite(np.asarray(out)))

    def test_loss_finite_positive(self, task):
        m = models.get_model(task)
        flat = m.init_flat(jax.random.PRNGKey(3))
        x = _example_input(m)
        loss = m.loss(flat, x, jnp.int32(0))
        assert float(loss) > 0.0 and np.isfinite(float(loss))

    def test_initial_loss_near_uniform(self, task):
        """Fresh init should predict ~uniformly: loss ≈ log(num_classes)."""
        m = models.get_model(task)
        flat = m.init_flat(jax.random.PRNGKey(4))
        losses = [float(m.loss(flat, _example_input(m, k), jnp.int32(0)))
                  for k in range(4)]
        assert np.mean(losses) < 3.0 * np.log(m.num_classes)

    def test_batched_forward_via_vmap(self, task):
        m = models.get_model(task)
        flat = m.init_flat(jax.random.PRNGKey(5))
        xs = jnp.stack([_example_input(m, k) for k in range(3)])
        outs = jax.vmap(lambda x: m.apply(flat, x))(xs)
        assert outs.shape == (3, m.num_classes)
        # batching must not change per-sample results
        solo = m.apply(flat, xs[1])
        np.testing.assert_allclose(outs[1], solo, rtol=1e-5, atol=1e-5)


class TestGradients:
    def test_grad_shape_and_nonzero(self, task):
        m = models.get_model(task)
        flat = m.init_flat(jax.random.PRNGKey(6))
        g = jax.grad(lambda p: m.loss(p, _example_input(m), jnp.int32(1)))(flat)
        assert g.shape == (m.num_params,)
        assert float(jnp.linalg.norm(g)) > 0.0
