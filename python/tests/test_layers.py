"""L2 layer library: shapes, parameter counts, numerics, DP-compat rules."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L


def _init(spec, fans, seed=0):
    return L.init_params(jax.random.PRNGKey(seed), spec, fans)


class TestDense:
    def test_shapes_and_values(self):
        spec, fans = L.dense_spec("d", 4, 3)
        p = _init(spec, fans)
        x = jnp.arange(4.0)
        y = L.dense(p, "d", x)
        assert y.shape == (3,)
        np.testing.assert_allclose(y, x @ p["d.w"] + p["d.b"], rtol=1e-6)

    def test_param_count(self):
        spec, _ = L.dense_spec("d", 10, 7)
        assert sum(int(np.prod(s)) for _, s in spec) == 10 * 7 + 7


class TestConv2d:
    def test_same_padding_shape(self):
        spec, fans = L.conv2d_spec("c", 1, 16, 8)
        p = _init(spec, fans)
        y = L.conv2d(p, "c", jnp.ones((28, 28, 1)), stride=2, padding="SAME")
        assert y.shape == (14, 14, 16)

    def test_valid_padding_shape(self):
        spec, fans = L.conv2d_spec("c", 16, 32, 4)
        p = _init(spec, fans)
        y = L.conv2d(p, "c", jnp.ones((13, 13, 16)), stride=2, padding="VALID")
        assert y.shape == (5, 5, 32)

    def test_identity_kernel(self):
        spec, fans = L.conv2d_spec("c", 1, 1, 1)
        p = {"c.w": jnp.ones((1, 1, 1, 1)), "c.b": jnp.zeros((1,))}
        x = jax.random.normal(jax.random.PRNGKey(0), (5, 5, 1))
        np.testing.assert_allclose(L.conv2d(p, "c", x), x, rtol=1e-6)


class TestPooling:
    def test_maxpool(self):
        x = jnp.arange(16.0).reshape(4, 4, 1)
        y = L.maxpool2d(x, 2, 2)
        np.testing.assert_allclose(y[:, :, 0], [[5, 7], [13, 15]])

    def test_avgpool(self):
        x = jnp.ones((4, 4, 2))
        y = L.avgpool2d(x, 2, 2)
        np.testing.assert_allclose(y, jnp.ones((2, 2, 2)), rtol=1e-6)


class TestEmbedding:
    def test_lookup(self):
        spec, fans = L.embedding_spec("e", 10, 4)
        p = _init(spec, fans)
        toks = jnp.array([3, 3, 7], jnp.int32)
        y = L.embedding(p, "e", toks)
        assert y.shape == (3, 4)
        np.testing.assert_allclose(y[0], y[1])
        np.testing.assert_allclose(y[0], p["e.emb"][3])


class TestNorms:
    def test_layernorm_normalizes(self):
        spec, fans = L.layernorm_spec("n", 64)
        p = {"n.g": jnp.ones(64), "n.b": jnp.zeros(64)}
        x = jax.random.normal(jax.random.PRNGKey(0), (64,)) * 10 + 3
        y = L.layernorm(p, "n", x)
        assert abs(float(jnp.mean(y))) < 1e-4
        assert abs(float(jnp.std(y)) - 1.0) < 1e-2

    def test_instancenorm_per_channel(self):
        p = {"n.g": jnp.ones(3), "n.b": jnp.zeros(3)}
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 3)) * 5 + 2
        y = L.instancenorm(p, "n", x)
        for c in range(3):
            assert abs(float(jnp.mean(y[:, :, c]))) < 1e-4

    def test_groupnorm_groups(self):
        p = {"n.g": jnp.ones(8), "n.b": jnp.zeros(8)}
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 4, 8)) * 3
        y = L.groupnorm(p, "n", x, groups=2)
        g0 = y[:, :, :4]
        assert abs(float(jnp.mean(g0))) < 1e-4

    def test_no_batch_statistics(self):
        """Per-sample invariance: normalizing one sample never depends on
        another — THE property BatchNorm violates (paper Appendix C)."""
        p = {"n.g": jnp.ones(16), "n.b": jnp.zeros(16)}
        xa = jax.random.normal(jax.random.PRNGKey(3), (16,))
        xb = jax.random.normal(jax.random.PRNGKey(4), (16,))
        solo = L.layernorm(p, "n", xa)
        batched = jax.vmap(lambda x: L.layernorm(p, "n", x))(
            jnp.stack([xa, xb]))
        np.testing.assert_allclose(solo, batched[0], rtol=1e-6)


class TestMha:
    def test_shape(self):
        spec, fans = L.mha_spec("a", 32)
        p = _init(spec, fans)
        y = L.mha(p, "a", jnp.ones((10, 32)), heads=4)
        assert y.shape == (10, 32)

    def test_softmax_rows_sum_to_one_effect(self):
        """With V = const, attention output is that const (rows sum to 1)."""
        spec, fans = L.mha_spec("a", 8)
        p = _init(spec, fans, seed=5)
        p = dict(p)
        p["a.v.w"] = jnp.zeros((8, 8))
        p["a.v.b"] = jnp.ones((8,))
        p["a.o.w"] = jnp.eye(8)
        p["a.o.b"] = jnp.zeros((8,))
        y = L.mha(p, "a", jax.random.normal(jax.random.PRNGKey(6), (5, 8)),
                  heads=2)
        np.testing.assert_allclose(y, jnp.ones((5, 8)), rtol=1e-5)


class TestRecurrent:
    @pytest.mark.parametrize("kind", ["rnn", "gru", "lstm"])
    def test_shapes(self, kind):
        spec_fn = {"rnn": L.rnn_spec, "gru": L.gru_spec, "lstm": L.lstm_spec}[kind]
        apply_fn = {"rnn": L.rnn, "gru": L.gru, "lstm": L.lstm}[kind]
        spec, fans = spec_fn("r", 6, 5)
        p = _init(spec, fans)
        y = apply_fn(p, "r", jnp.ones((7, 6)), 5)
        assert y.shape == (7, 5)

    @pytest.mark.parametrize("kind", ["rnn", "lstm"])
    def test_fused_equals_naive(self, kind):
        """The optimized (fused) and naive cells are the same function."""
        spec_fn = {"rnn": L.rnn_spec, "lstm": L.lstm_spec}[kind]
        apply_fn = {"rnn": L.rnn, "lstm": L.lstm}[kind]
        spec, fans = spec_fn("r", 4, 3)
        p = _init(spec, fans, seed=7)
        x = jax.random.normal(jax.random.PRNGKey(8), (9, 4))
        yf = apply_fn(p, "r", x, 3, fused=True)
        yn = apply_fn(p, "r", x, 3, fused=False)
        np.testing.assert_allclose(yf, yn, rtol=1e-5, atol=1e-6)

    def test_gru_fused_equals_naive(self):
        spec, fans = L.gru_spec("r", 4, 3)
        p = _init(spec, fans, seed=9)
        x = jax.random.normal(jax.random.PRNGKey(10), (6, 4))
        np.testing.assert_allclose(L.gru(p, "r", x, 3, fused=True),
                                   L.gru(p, "r", x, 3, fused=False),
                                   rtol=1e-5, atol=1e-6)

    def test_lstm_param_count_torch_style(self):
        """Double biases, like torch.nn.LSTM (paper's 1,081,002 count)."""
        spec, _ = L.lstm_spec("r", 100, 100)
        n = sum(int(np.prod(s)) for _, s in spec)
        assert n == 4 * (100 * 100 + 100 * 100 + 100 + 100) == 80800


class TestLoss:
    def test_softmax_xent_matches_manual(self):
        logits = jnp.array([1.0, 2.0, 3.0])
        want = -jnp.log(jnp.exp(2.0) / jnp.sum(jnp.exp(logits)))
        np.testing.assert_allclose(L.softmax_xent(logits, jnp.int32(1)),
                                   want, rtol=1e-6)

    def test_uniform_logits(self):
        k = 10
        loss = L.softmax_xent(jnp.zeros(k), jnp.int32(3))
        np.testing.assert_allclose(loss, math.log(k), rtol=1e-6)
