#!/usr/bin/env bash
# Before/after GEMM bench comparison between two git refs.
#
# Usage: scripts/perf_compare.sh BEFORE_REF [AFTER_REF]
#        ITERS_SCALE=0.2 scripts/perf_compare.sh v0 HEAD   # quicker run
#
# Checks each ref out into a temporary git worktree, runs
# `cargo bench --bench gemm_kernels -- --bench-out ...` in each, and
# prints a joined per-shape speedup table (after vs before, on the
# blocked_gflops column both the PR-5 and PR-7 bench schemas emit).
# AFTER_REF defaults to the current HEAD. No --check: a slow "before"
# ref must not abort the comparison.
set -euo pipefail

repo_root="$(git rev-parse --show-toplevel)"
before_ref="${1:?usage: scripts/perf_compare.sh BEFORE_REF [AFTER_REF]}"
after_ref="${2:-HEAD}"
scale="${ITERS_SCALE:-1.0}"

tmp="$(mktemp -d)"
cleanup() {
    git -C "$repo_root" worktree remove --force "$tmp/before" >/dev/null 2>&1 || true
    git -C "$repo_root" worktree remove --force "$tmp/after" >/dev/null 2>&1 || true
    rm -rf "$tmp"
}
trap cleanup EXIT

run_ref() {
    local ref="$1" dir="$2" out="$3"
    echo "== benching $ref" >&2
    git -C "$repo_root" worktree add --detach "$dir" "$ref" >/dev/null
    (cd "$dir/rust" && cargo bench --bench gemm_kernels -- \
        --iters-scale "$scale" --bench-out "$out" >&2)
}

run_ref "$before_ref" "$tmp/before" "$tmp/before.json"
run_ref "$after_ref" "$tmp/after" "$tmp/after.json"

python3 - "$tmp/before.json" "$tmp/after.json" "$before_ref" "$after_ref" <<'EOF'
import json
import sys

before_path, after_path, before_ref, after_ref = sys.argv[1:5]
with open(before_path) as f:
    before = json.load(f)["shapes"]
with open(after_path) as f:
    after = json.load(f)["shapes"]

rows = [(name, before[name], a) for name, a in after.items() if before.get(name)]
if not rows:
    sys.exit("no shapes present in both refs")
w = max(len(n) for n, _, _ in rows)
print(f"gemm_kernels: {before_ref} -> {after_ref} (blocked_gflops per shape)")
print(f"{'shape':<{w}}  {'before GF/s':>12}  {'after GF/s':>11}  speedup")
for name, b, a in rows:
    bg, ag = b["blocked_gflops"], a["blocked_gflops"]
    print(f"{name:<{w}}  {bg:>12.2f}  {ag:>11.2f}  {ag / bg:>6.2f}x")
missing = sorted(set(before) ^ set(after))
if missing:
    print(f"not in both refs (skipped): {', '.join(missing)}")
EOF
