#!/usr/bin/env python3
"""Schema validator for the opacus-rs observability artifacts.

Usage:
    validate_obs.py trace FILE     chrome://tracing export from --trace
    validate_obs.py metrics FILE   metrics JSON written by --out
    validate_obs.py status FILE    per-job status.json written by serve

Checks structure only (stdlib json, no dependencies) so CI can gate on
the exported files without loading them into a UI. Exits non-zero with
a one-line reason on the first violation.
"""

import json
import sys

TRACE_FORMAT = "opacus-rs/trace"
STATUS_FORMAT = "opacus-rs/status"


def is_count(v):
    """Counters go through the f64 JSON writer; accept integral floats."""
    return isinstance(v, (int, float)) and v >= 0 and float(v).is_integer()


def fail(msg):
    print(f"validate_obs: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def require(cond, msg):
    if not cond:
        fail(msg)


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{path}: {e}")


def check_trace(path):
    doc = load(path)
    require(isinstance(doc, dict), "trace root must be an object")
    other = doc.get("otherData", {})
    require(other.get("format") == TRACE_FORMAT,
            f"otherData.format must be {TRACE_FORMAT!r}, got {other.get('format')!r}")
    require(is_count(other.get("version")), "otherData.version must be an integer")
    require(doc.get("displayTimeUnit") == "ms", "displayTimeUnit must be 'ms'")

    events = doc.get("traceEvents")
    require(isinstance(events, list) and events, "traceEvents must be a non-empty array")

    named_lanes = set()      # (pid, tid) with a thread_name metadata event
    span_lanes = set()       # (pid, tid) carrying at least one span
    spans = []
    for i, e in enumerate(events):
        require(isinstance(e, dict), f"event {i} must be an object")
        ph = e.get("ph")
        require(ph in ("X", "M"), f"event {i}: unknown phase {ph!r}")
        require(is_count(e.get("pid")) and is_count(e.get("tid")),
                f"event {i}: pid/tid must be integers")
        key = (e["pid"], e["tid"])
        if ph == "M":
            require(e.get("name") == "thread_name",
                    f"event {i}: only thread_name metadata is emitted")
            name = e.get("args", {}).get("name")
            require(isinstance(name, str) and name, f"event {i}: lane name must be a string")
            named_lanes.add(key)
        else:
            for field in ("ts", "dur"):
                v = e.get(field)
                require(isinstance(v, (int, float)) and v >= 0,
                        f"event {i}: {field} must be a non-negative number")
            require(isinstance(e.get("name"), str) and e["name"], f"event {i}: span needs a name")
            require(isinstance(e.get("cat"), str) and e["cat"], f"event {i}: span needs a category")
            span_lanes.add(key)
            spans.append(e)

    require(spans, "trace carries no spans")
    require(span_lanes <= named_lanes,
            f"every lane with spans needs a thread_name event; unnamed: {span_lanes - named_lanes}")

    # coverage: the root cli span must cover >=95% of the trace's span extent
    roots = [e for e in spans if e["cat"] == "cli"]
    if roots:
        root = max(roots, key=lambda e: e["dur"])
        lo = min(e["ts"] for e in spans)
        hi = max(e["ts"] + e["dur"] for e in spans)
        extent = hi - lo
        require(extent > 0, "trace extent must be positive")
        cover = root["dur"] / extent
        require(cover >= 0.95,
                f"root '{root['name']}' span covers {cover:.1%} of the trace extent (< 95%)")

    print(f"validate_obs: trace OK — {len(spans)} spans on {len(span_lanes)} named lane(s), "
          f"{other.get('dropped_events', 0)} dropped")


def check_hist(name, h):
    require(isinstance(h, dict), f"histogram {name} must be an object")
    count = h.get("count")
    require(is_count(count), f"histogram {name}: bad count")
    require(isinstance(h.get("sum"), (int, float)), f"histogram {name}: bad sum")
    buckets = h.get("buckets")
    require(isinstance(buckets, list), f"histogram {name}: buckets must be an array")
    total = 0
    for b in buckets:
        require(isinstance(b, list) and len(b) == 2, f"histogram {name}: bucket must be [idx, n]")
        total += b[1]
    require(total == count, f"histogram {name}: bucket counts {total} != count {count}")
    if count > 0:
        require(isinstance(h.get("min"), (int, float)) and isinstance(h.get("max"), (int, float)),
                f"histogram {name}: min/max required when count > 0")


def check_obs_snapshot(obs):
    require(is_count(obs.get("version")), "obs.version must be an integer")
    counters = obs.get("counters", {})
    require(isinstance(counters, dict), "obs.counters must be an object")
    for k, v in counters.items():
        require(is_count(v), f"counter {k} must be a non-negative integer")
    hists = obs.get("histograms", {})
    require(isinstance(hists, dict), "obs.histograms must be an object")
    for k, h in hists.items():
        check_hist(k, h)
    return len(counters), len(hists)


def check_metrics(path):
    doc = load(path)
    require(isinstance(doc, dict), "metrics root must be an object")
    require(isinstance(doc.get("records"), list), "metrics.records must be an array")
    if "obs" in doc:
        nc, nh = check_obs_snapshot(doc["obs"])
        print(f"validate_obs: metrics OK — {len(doc['records'])} records, "
              f"obs snapshot with {nc} counter(s), {nh} histogram(s)")
    else:
        print(f"validate_obs: metrics OK — {len(doc['records'])} records (no obs snapshot)")


def check_status(path):
    doc = load(path)
    require(isinstance(doc, dict), "status root must be an object")
    require(doc.get("format") == STATUS_FORMAT,
            f"format must be {STATUS_FORMAT!r}, got {doc.get('format')!r}")
    require(is_count(doc.get("version")), "version must be an integer")
    require(doc.get("state") in ("running", "exhausted", "completed", "interrupted", "failed"),
            f"unknown state {doc.get('state')!r}")
    require(isinstance(doc.get("task"), str) and doc["task"], "task must be a string")
    for field in ("job", "step", "epoch"):
        require(is_count(doc.get(field)),
                f"{field} must be a non-negative integer, got {doc.get(field)!r}")
    for field in ("steps_per_sec", "epsilon", "epsilon_budget", "budget_burn",
                  "sigma", "compute_secs", "reduce_secs"):
        v = doc.get(field)
        require(isinstance(v, (int, float)) and v >= 0, f"{field} must be a non-negative number")
    require(doc["budget_burn"] <= 1.0, "budget_burn must be <= 1.0")
    if doc["epsilon_budget"] > 0:
        require(doc["epsilon"] <= doc["epsilon_budget"] + 1e-12,
                "ε must not exceed a positive budget")
    for field in ("worker_respawns", "checkpoint_retries", "checkpoint_rollbacks"):
        require(is_count(doc.get(field)),
                f"{field} must be a non-negative integer, got {doc.get(field)!r}")
    if doc["state"] == "failed":
        require(isinstance(doc.get("error"), str) and doc["error"],
                "a failed status must carry a non-empty error string")
    else:
        require("error" not in doc, "error is only valid when state is 'failed'")
    print(f"validate_obs: status OK — job {doc['job']} ({doc['task']}) {doc['state']} "
          f"at step {doc['step']}, ε = {doc['epsilon']}, "
          f"recovery: {doc['worker_respawns']} respawn(s), "
          f"{doc['checkpoint_retries']} retry(ies), {doc['checkpoint_rollbacks']} rollback(s)")


def main():
    if len(sys.argv) != 3 or sys.argv[1] not in ("trace", "metrics", "status"):
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    {"trace": check_trace, "metrics": check_metrics, "status": check_status}[sys.argv[1]](
        sys.argv[2]
    )


if __name__ == "__main__":
    main()
